import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below may touch jax.
"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes, record memory/cost/collective analysis per cell.

  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch qwen2-0.5b
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

Results cache to experiments/dryrun/<arch>__<shape>__<mesh>.json; re-runs
skip cells that already succeeded (delete the file to force).
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import registry, specs
from repro.configs.shapes import cells
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import cell_shardings
from repro import roofline as rl

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def out_path(arch, shape, mesh_kind, opt=False):
    sfx = "__opt" if opt else ""
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_kind}{sfx}.json")


def _compile_cost(arch, shape_id, mesh, mesh_axes, cfg):
    """flops / bytes-accessed of one probe config (unrolled layers)."""
    step, args, meta = specs.build_cell(arch, shape_id, mesh_axes=mesh_axes,
                                        cfg_override=cfg)
    in_sh = cell_shardings(arch, shape_id, args, meta, mesh)
    in_sh = jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), in_sh,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    with mesh:
        cost = (jax.jit(step, in_shardings=in_sh).lower(*args)
                .compile().cost_analysis()) or {}
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)))


def lm_corrected_cost(arch, shape_id, mesh, mesh_axes, cfg):
    """True per-step flops/bytes: XLA cost analysis counts while bodies
    once, so probe with 1-2 *unrolled* layers and extrapolate linearly to
    the full depth (exact for homogeneous scan blocks)."""
    import dataclasses as dc
    if cfg.moe is not None and cfg.n_dense_layers > 0:
        nd, nm = cfg.n_dense_layers, cfg.n_layers - cfg.n_dense_layers
        p1 = _compile_cost(arch, shape_id, mesh, mesh_axes,
                           dc.replace(cfg, n_layers=2, n_dense_layers=1,
                                      unroll=True))
        p2 = _compile_cost(arch, shape_id, mesh, mesh_axes,
                           dc.replace(cfg, n_layers=3, n_dense_layers=2,
                                      unroll=True))
        p3 = _compile_cost(arch, shape_id, mesh, mesh_axes,
                           dc.replace(cfg, n_layers=3, n_dense_layers=1,
                                      unroll=True))
        fd = tuple(b - a for a, b in zip(p1, p2))
        fm = tuple(b - a for a, b in zip(p1, p3))
        base = tuple(a - d - m for a, d, m in zip(p1, fd, fm))
        return tuple(b + nd * d + nm * m
                     for b, d, m in zip(base, fd, fm))
    ltot = cfg.n_layers
    p1 = _compile_cost(arch, shape_id, mesh, mesh_axes,
                       dc.replace(cfg, n_layers=1, unroll=True))
    p2 = _compile_cost(arch, shape_id, mesh, mesh_axes,
                       dc.replace(cfg, n_layers=2, unroll=True))
    per = tuple(b - a for a, b in zip(p1, p2))
    return tuple(a + (ltot - 1) * d for a, d in zip(p1, per))


def run_cell(arch: str, shape_id: str, mesh_kind: str,
             opt: bool = False) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = 512 if multi else 256
    dp = ("pod", "data") if multi else ("data",)
    step, args, meta = specs.build_cell(arch, shape_id,
                                        mesh_axes=(dp, "model"), opt=opt)
    in_sh = cell_shardings(arch, shape_id, args, meta, mesh)
    in_sh = jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), in_sh,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    rec = dict(arch=arch, shape=shape_id, mesh=mesh_kind, chips=chips,
               kind=meta["kind"], opt=opt, ok=False)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, in_shardings=in_sh).lower(*args)
        rec["t_lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["t_compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem,
                                            "generated_code_size_in_bytes",
                                            None),
        }
        cost = compiled.cost_analysis() or {}
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float))}
        hlo = compiled.as_text()
        model_flops = None
        if registry.family_of(arch) == "lm":
            from repro.configs.shapes import LM_SHAPES
            sh = LM_SHAPES[shape_id]
            tokens = (sh["global_batch"] * sh["seq_len"]
                      if meta["kind"] in ("train", "prefill")
                      else sh["global_batch"])
            model_flops = rl.lm_model_flops(
                meta["cfg"], tokens, training=meta["kind"] == "train")
        # scan-aware HLO cost (XLA cost analysis counts loop bodies once)
        cflops, cbytes = rl.hlo_cost(hlo)
        rec["cost_raw"] = {"flops": cost.get("flops"),
                           "bytes accessed": cost.get("bytes accessed")}
        cost = dict(cost)
        cost["flops"] = cflops
        cost["bytes accessed"] = cbytes
        roof = rl.roofline_from(cost, hlo, chips=chips,
                                model_flops=model_flops)
        rec["roofline"] = roof.to_dict()
        rec["ok"] = True
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="apply the beyond-baseline optimizations")
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)

    todo = cells() if args.all or args.arch is None else [
        (args.arch, s) for a, s in cells()
        if a == args.arch and (args.shape is None or s == args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_ok = n_fail = n_skip = 0
    for mesh_kind in meshes:
        for arch, shape_id in todo:
            path = out_path(arch, shape_id, mesh_kind, args.opt)
            if os.path.exists(path) and not args.force:
                with open(path) as f:
                    if json.load(f).get("ok"):
                        n_skip += 1
                        continue
            print(f"[dryrun] {arch} x {shape_id} x {mesh_kind} ...",
                  flush=True)
            try:
                rec = run_cell(arch, shape_id, mesh_kind, opt=args.opt)
            except Exception as e:  # noqa: BLE001 — record and continue
                rec = dict(arch=arch, shape=shape_id, mesh=mesh_kind,
                           ok=False, error=f"{type(e).__name__}: {e}",
                           traceback=traceback.format_exc()[-4000:])
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec["ok"]:
                n_ok += 1
                r = rec["roofline"]
                print(f"    ok  compile={rec['t_compile_s']}s "
                      f"dominant={r['dominant']} "
                      f"t=(c {r['t_compute']:.2e}, m {r['t_memory']:.2e}, "
                      f"x {r['t_collective']:.2e})", flush=True)
            else:
                n_fail += 1
                print(f"    FAIL {rec.get('error', '')[:300]}", flush=True)
    print(f"[dryrun] done ok={n_ok} fail={n_fail} skipped={n_skip}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())

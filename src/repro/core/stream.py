"""Streaming graph updates: edge deltas, versioned graphs, invalidation.

Serving real traffic means the graph drifts (ROADMAP "streaming graphs"
rung).  This module is the host-side delta layer the incremental solve
path (``IMMSolver.resolve_incremental``) and the serving registry build
on, following Wang et al.'s space-efficient RR-pool maintenance
(PAPERS.md, arXiv 2311.07554) while keeping gIM/IMM's exact-IC contract:

* :func:`apply_edge_deltas` — apply edge adds/removes to a
  :class:`~repro.graph.csr.CSRGraph`.  Added parallels merge through the
  existing :func:`~repro.graph.csr.coalesce_ic` (p' = 1 − ∏(1 − p_i)),
  which is *distribution-exact* under IC, so the post-delta graph is a
  plain simple CSR every engine already handles — no special streaming
  sampler.
* :func:`affected_nodes` — the invalidation frontier of a delta batch.
  A forward edge u→v lives in row v of the *reverse* sampling graph, and
  an RR-BFS only ever examines the reverse-adjacency rows of nodes it
  visits.  Therefore a pre-delta RR set that contains **no** destination
  of any changed edge examined only unchanged rows: its trajectory has
  identical probability under both graphs, and the event itself is
  trajectory-measurable — surviving rows are exact post-delta samples
  conditioned on avoiding the changed rows (DESIGN.md §9 states the
  precise guarantee and the residual conditioning term the conformance
  suite polices).
* :class:`VersionedGraph` — a graph handle carrying a monotone
  ``version`` plus the content :func:`~repro.graph.csr.graph_digest`;
  the serving registry threads the digest through its solver key and the
  result-cache key so a mutated graph can never serve a stale pool or
  cached result.

Everything here is host-side numpy; no jax imports.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.graph.csr import (CSRGraph, coalesce_ic, from_edges, graph_digest,
                             to_edges)


@dataclass(frozen=True)
class EdgeDeltas:
    """One batch of edge mutations against a CSR graph.

    ``add_src``/``add_dst``/``add_p`` — forward edges to insert with their
    IC probabilities (an edge that already exists merges IC-exactly:
    p' = 1 − (1 − p_old)(1 − p_new)).  ``rm_src``/``rm_dst`` — forward
    edges to delete; removal drops *every* parallel (u, v) edge, i.e. the
    IC-merged edge disappears entirely.
    """
    add_src: np.ndarray
    add_dst: np.ndarray
    add_p: np.ndarray
    rm_src: np.ndarray
    rm_dst: np.ndarray

    @property
    def n_adds(self) -> int:
        return int(self.add_src.shape[0])

    @property
    def n_removes(self) -> int:
        return int(self.rm_src.shape[0])

    def __bool__(self) -> bool:
        return bool(self.n_adds or self.n_removes)


def make_deltas(adds=None, removes=None) -> EdgeDeltas:
    """Normalize delta specs into an :class:`EdgeDeltas`.

    ``adds`` — ``(src, dst, p)`` array triple; ``removes`` — ``(src, dst)``
    array pair.  Either may be ``None`` (empty).
    """
    if adds is None:
        a_s = a_d = np.zeros(0, np.int64)
        a_p = np.zeros(0, np.float32)
    else:
        a_s, a_d, a_p = (np.asarray(adds[0], np.int64).reshape(-1),
                         np.asarray(adds[1], np.int64).reshape(-1),
                         np.asarray(adds[2], np.float32).reshape(-1))
        if not (a_s.shape == a_d.shape == a_p.shape):
            raise ValueError("adds must be aligned (src, dst, p) arrays")
        if a_p.size and ((a_p < 0).any() or (a_p > 1).any()
                         or not np.isfinite(a_p).all()):
            raise ValueError("added edge probabilities must lie in [0, 1]")
    if removes is None:
        r_s = r_d = np.zeros(0, np.int64)
    else:
        r_s, r_d = (np.asarray(removes[0], np.int64).reshape(-1),
                    np.asarray(removes[1], np.int64).reshape(-1))
        if r_s.shape != r_d.shape:
            raise ValueError("removes must be aligned (src, dst) arrays")
    return EdgeDeltas(add_src=a_s, add_dst=a_d, add_p=a_p,
                      rm_src=r_s, rm_dst=r_d)


def as_deltas(deltas) -> EdgeDeltas:
    """Accept an :class:`EdgeDeltas` or an ``(adds, removes)`` pair."""
    if isinstance(deltas, EdgeDeltas):
        return deltas
    adds, removes = deltas
    return make_deltas(adds, removes)


def affected_nodes(deltas: EdgeDeltas) -> np.ndarray:
    """Sorted unique destinations of every changed forward edge — the
    nodes whose reverse-adjacency row the deltas touch.  An RR set
    containing none of them provably never examined a changed row (see
    module docstring), so it survives :meth:`IMMSolver.resolve_incremental`
    unchanged."""
    d = as_deltas(deltas)
    return np.unique(np.concatenate([d.add_dst, d.rm_dst]))


def apply_edge_deltas(g: CSRGraph, adds=None, removes=None,
                      *, strict: bool = True) -> CSRGraph:
    """Apply edge adds/removes to ``g``; returns a new coalesced CSR.

    Removal semantics are IC-merged: removing (u, v) deletes *all*
    parallel (u, v) edges.  Additions append and then coalesce —
    re-adding an existing edge strengthens it IC-exactly
    (p' = 1 − (1 − p_old)(1 − p_new)).  With ``strict`` (default), a
    removal naming an absent edge raises ``ValueError`` — a caller
    tracking graph state that disagrees with the graph is a bug worth
    surfacing; ``strict=False`` ignores such removals.
    """
    d = as_deltas((adds, removes)) if not isinstance(adds, EdgeDeltas) \
        else adds
    n = g.n_nodes
    for name, arr in (("add_src", d.add_src), ("add_dst", d.add_dst),
                      ("rm_src", d.rm_src), ("rm_dst", d.rm_dst)):
        if arr.size and ((arr < 0).any() or (arr >= n).any()):
            raise ValueError(f"{name} endpoint out of range [0, {n})")
    src, dst, w = to_edges(g)
    if d.n_removes:
        # pair-encode (u, v) -> u*n + v for a vectorized membership test
        keys = src * n + dst
        rm_keys = np.unique(d.rm_src * n + d.rm_dst)
        if strict:
            present = np.isin(rm_keys, keys)
            if not present.all():
                miss = rm_keys[~present][0]
                raise ValueError(
                    f"cannot remove absent edge "
                    f"({int(miss // n)}, {int(miss % n)}); pass "
                    "strict=False to ignore missing removals")
        keep = ~np.isin(keys, rm_keys)
        src, dst, w = src[keep], dst[keep], w[keep]
    if d.n_adds:
        src = np.concatenate([src, d.add_src])
        dst = np.concatenate([dst, d.add_dst])
        w = np.concatenate([w.astype(np.float32), d.add_p])
    return coalesce_ic(from_edges(src, dst, n, weights=w, sort_rows=True))


@dataclass(frozen=True)
class VersionedGraph:
    """A graph handle with a monotone version and its content digest —
    the identity streamed graphs carry through the serving layer."""
    g: CSRGraph
    version: int
    digest: str

    @classmethod
    def wrap(cls, g: CSRGraph, version: int = 0) -> "VersionedGraph":
        return cls(g=g, version=version, digest=graph_digest(g))

    def apply(self, deltas, *, strict: bool = True) -> "VersionedGraph":
        """Monotone step: apply a delta batch, bump the version, re-digest."""
        d = as_deltas(deltas) if not isinstance(deltas, EdgeDeltas) else deltas
        ng = apply_edge_deltas(self.g, d, strict=strict)
        return VersionedGraph(g=ng, version=self.version + 1,
                              digest=graph_digest(ng))


__all__ = ["EdgeDeltas", "VersionedGraph", "affected_nodes",
           "apply_edge_deltas", "as_deltas", "make_deltas"]

"""SamplerEngine protocol + registry: one API for every RR-sampling engine.

The paper's claim that "other variations of the IM problem need only minor
modifications" (§3.7 LT, §4.8 MRIM) becomes a first-class contract here:
every sampling engine — the gIM queue decomposition, the dense-frontier
reference, the persistent-lane refill worker, the LT walk sampler, and
MRIM's round-tagged variant — is an adapter class that

* is configured by a per-engine ``Config`` dataclass,
* is registered under a short name (``register_engine`` / ``get_engine``),
* returns one canonical :class:`RRBatch` from ``sample(key)``.

Downstream (``IMMSolver``, ``solve_mrim``, the sharded launch pipeline,
benchmarks) consumes only the protocol, so adding a diffusion model means
writing one adapter — no solver changes.  See DESIGN.md §3.

Layering: this module imports the low-level samplers (``rrset``, ``dense``,
``lt``); it is imported by the solvers (``imm``, ``mrim``) and launchers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph
from repro.core import rrset as rr_queue
from repro.core import dense as rr_dense
from repro.core import lt as rr_lt
from repro.core.packing import pack_rows


class RRBatch(NamedTuple):
    """Canonical, device-resident result of one ``SamplerEngine.sample`` call.

    One row per completed RR set; rows are padded to the batch's max length.
    ``nodes`` entries beyond ``lengths[i]`` are undefined (consumers mask by
    length — ``coverage.build_store`` / ``IncrementalRRStore.append_batch``
    do).  Node ids live in the engine's ``item_space`` (plain engines:
    ``[0, n)``; MRIM: ``round * n + node`` in ``[0, n * t_rounds)``).

    ``overflowed`` is per *lane* (engines whose lanes each emit one set have
    lanes == rows; the refill engine reports its persistent lanes).
    ``steps`` is the scalar count of lockstep micro-steps this batch cost —
    the hardware-transferable parallel-time metric of §Perf/IM.
    """
    nodes: jnp.ndarray       # (R, W) int32/int64, padded per-set node ids
    lengths: jnp.ndarray     # (R,) int — RR-set sizes (>= 1)
    overflowed: jnp.ndarray  # (L,) bool — per-lane truncation flags
    steps: jnp.ndarray       # () int — lockstep micro-steps executed

    @property
    def n_sets(self) -> int:
        return int(self.lengths.shape[0])

    @classmethod
    def make(cls, nodes, lengths, overflowed, steps) -> "RRBatch":
        return cls(nodes=jnp.asarray(nodes), lengths=jnp.asarray(lengths),
                   overflowed=jnp.asarray(overflowed),
                   steps=jnp.asarray(steps))


@runtime_checkable
class SamplerEngine(Protocol):
    """What the solvers require of an engine (structural — no inheritance)."""
    name: str

    @property
    def item_space(self) -> int:
        """Size of the id space ``nodes`` draws from (coverage histogram n)."""
        ...

    def sample(self, key) -> RRBatch:
        """Sample one batch of RR sets; ``key`` is a jax PRNG key."""
        ...


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ENGINES: dict[str, type] = {}

# engines living outside core (to avoid core -> launch import cycles) are
# resolved by importing their home module on first lookup
_LAZY_ENGINES: dict[str, str] = {"queue_sharded": "repro.launch.im_solve"}


def register_engine(name: str):
    """Class decorator: register ``cls`` under ``name`` (sets ``cls.name``)."""
    def deco(cls):
        cls.name = name
        _ENGINES[name] = cls
        return cls
    return deco


def get_engine(name: str) -> type:
    if name not in _ENGINES and name in _LAZY_ENGINES:
        import importlib
        importlib.import_module(_LAZY_ENGINES[name])
    try:
        return _ENGINES[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; registered: "
                       f"{sorted(set(_ENGINES) | set(_LAZY_ENGINES))}"
                       ) from None


def list_engines() -> list[str]:
    return sorted(set(_ENGINES) | set(_LAZY_ENGINES))


def make_engine(name: str, g_rev: CSRGraph, **opts) -> "SamplerEngine":
    """Instantiate a registered engine on the reverse graph.

    ``opts`` may be a superset of the engine's ``Config`` fields — unknown
    keys and ``None`` values are dropped, so callers (``IMMSolver``) can pass
    one uniform option set (batch/qcap/ec/...) to any engine.
    """
    cls = get_engine(name)
    fields = {f.name for f in dataclasses.fields(cls.Config)}
    cfg = cls.Config(**{k: v for k, v in opts.items()
                        if k in fields and v is not None})
    return cls(g_rev, cfg)


def resolve_engine_name(engine: str, model: str = "ic") -> str:
    """Back-compat mapping from the old (engine, model) pair to an engine
    name: ``model="lt"`` overrides the IC engine choice (the LT walk sampler
    is the only LT engine)."""
    return "lt" if model == "lt" else engine


def resolve_qcap(qcap: Optional[int], g_rev: CSRGraph) -> int:
    """Default queue capacity: the whole node set (an RR set can never be
    larger, so the default never overflows)."""
    return qcap if qcap is not None else g_rev.n_nodes


# ---------------------------------------------------------------------------
# Engine adapters
# ---------------------------------------------------------------------------

@register_engine("queue")
class QueueEngine:
    """gIM-faithful work-efficient sampler (paper Alg. 3/6; core/rrset.py)."""

    @dataclass(frozen=True)
    class Config:
        batch: int = 256
        qcap: Optional[int] = None   # default: n_nodes
        ec: int = rr_queue.EC_DEFAULT

    def __init__(self, g_rev: CSRGraph, config: Optional[Config] = None):
        self.g_rev = g_rev
        self.config = config if config is not None else self.Config()
        self.qcap = resolve_qcap(self.config.qcap, g_rev)

    @property
    def item_space(self) -> int:
        return self.g_rev.n_nodes

    def sample(self, key) -> RRBatch:
        s = rr_queue.sample_rrsets_queue(key, self.g_rev, self.config.batch,
                                         self.qcap, self.config.ec)
        return RRBatch.make(s.nodes, s.lengths, s.overflowed, s.steps)


@register_engine("dense")
class DenseEngine:
    """Dense-frontier masked-SpMV sampler (core/dense.py); membership is
    converted to padded rows by one vectorized rank-scatter (no per-row
    python ``nonzero`` loop)."""

    @dataclass(frozen=True)
    class Config:
        batch: int = 256

    def __init__(self, g_rev: CSRGraph, config: Optional[Config] = None):
        self.g_rev = g_rev
        self.config = config if config is not None else self.Config()

    @property
    def item_space(self) -> int:
        return self.g_rev.n_nodes

    def sample(self, key) -> RRBatch:
        s = rr_dense.sample_rrsets_dense(key, self.g_rev, self.config.batch)
        nodes, lens = rr_dense.membership_to_padded(s.membership)
        overflow = np.zeros(self.config.batch, bool)  # dense never truncates
        return RRBatch.make(nodes, lens, overflow, s.levels)


@register_engine("refill")
class RefillEngine:
    """Persistent-lane worker (paper Alg. 6): lanes refill with fresh roots
    until ``batch`` RR sets are complete; a sample may return slightly more
    than ``batch`` rows (in-flight sets always finish, unbiased)."""

    @dataclass(frozen=True)
    class Config:
        batch: int = 256             # quota: target RR sets per sample()
        lanes: Optional[int] = None  # default: batch//4 clamped to [8, 256]
        out_cap: Optional[int] = None
        ec: int = rr_queue.EC_DEFAULT

    def __init__(self, g_rev: CSRGraph, config: Optional[Config] = None):
        self.g_rev = g_rev
        cfg = config if config is not None else self.Config()
        self.config = cfg
        self.lanes = (cfg.lanes if cfg.lanes is not None
                      else max(min(cfg.batch // 4, 256), 8))
        self.out_cap = (cfg.out_cap if cfg.out_cap is not None
                        else min(8 * cfg.batch // self.lanes, 64) * 64)

    @property
    def item_space(self) -> int:
        return self.g_rev.n_nodes

    def sample(self, key) -> RRBatch:
        s = rr_queue.sample_rrsets_refill(key, self.g_rev, self.lanes,
                                          quota=self.config.batch,
                                          out_cap=self.out_cap,
                                          ec=self.config.ec)
        nodes, lens = rr_queue.refill_to_padded(s)
        return RRBatch.make(nodes, lens, s.overflowed, s.steps)


@register_engine("lt")
class LTEngine:
    """Linear-threshold walk sampler (paper §3.7; core/lt.py)."""

    @dataclass(frozen=True)
    class Config:
        batch: int = 256
        qcap: Optional[int] = None

    def __init__(self, g_rev: CSRGraph, config: Optional[Config] = None):
        self.g_rev = g_rev
        self.config = config if config is not None else self.Config()
        self.qcap = resolve_qcap(self.config.qcap, g_rev)

    @property
    def item_space(self) -> int:
        return self.g_rev.n_nodes

    def sample(self, key) -> RRBatch:
        s = rr_lt.sample_rrsets_lt(key, self.g_rev, self.config.batch,
                                   self.qcap)
        return RRBatch.make(s.nodes, s.lengths, s.overflowed, s.steps)


@register_engine("mrim")
class MRIMEngine:
    """Multi-round IM sampler (paper §4.8): each RR sample is T tagged BFS
    from a shared root, run as T adjacent queue-engine lanes; elements are
    encoded ``round * n + node`` so coverage machinery is reused verbatim on
    an item space of n·T.  Lane segments are merged into one padded row per
    sample by a vectorized rank-scatter (no per-sample python loop)."""

    @dataclass(frozen=True)
    class Config:
        batch: int = 64
        t_rounds: int = 2
        qcap: Optional[int] = None
        ec: int = rr_queue.EC_DEFAULT

    def __init__(self, g_rev: CSRGraph, config: Optional[Config] = None):
        self.g_rev = g_rev
        self.config = config if config is not None else self.Config()
        self.qcap = resolve_qcap(self.config.qcap, g_rev)

    @property
    def item_space(self) -> int:
        return self.g_rev.n_nodes * self.config.t_rounds

    def sample(self, key) -> RRBatch:
        g_rev, cfg, qcap = self.g_rev, self.config, self.qcap
        n, m = g_rev.n_nodes, g_rev.n_edges
        t = cfg.t_rounds
        key, kroot, ksample = jax.random.split(key, 3)
        roots = jax.random.randint(kroot, (cfg.batch,), 0, n, dtype=jnp.int32)
        tiled_roots = jnp.repeat(roots, t)            # lane b*T+r -> root b
        nodes, lengths, overflowed, steps = rr_queue._sample_queue(
            ksample, g_rev.offsets, g_rev.indices, g_rev.weights, tiled_roots,
            batch=cfg.batch * t, qcap=qcap, ec=cfg.ec, n=n, m=m)
        rounds = np.tile(np.arange(t, dtype=np.int64), cfg.batch)
        enc = (np.asarray(nodes).astype(np.int64) + (rounds * n)[:, None]
               ).reshape(cfg.batch, t * qcap)
        lane_len = np.asarray(lengths).reshape(cfg.batch, t)
        # valid positions: within each lane's segment, first lane_len entries
        seg = np.arange(t * qcap) // qcap
        pos = np.arange(t * qcap) % qcap
        mask = pos[None, :] < lane_len[:, seg]
        out_nodes, out_lens = pack_rows(np.asarray(enc), mask)
        overflow = np.asarray(overflowed).reshape(cfg.batch, t).any(axis=1)
        return RRBatch.make(out_nodes, out_lens, overflow, steps)

"""Weighted root sampling: Walker alias tables for weighted IM.

Weighted influence maximization (Cohen et al., sketch-based IM) weights each
node's contribution to the objective: ``Σ_v w_v · P[v influenced]``.  Under
RIS this is *one* change to the pipeline — draw RR roots ∝ ``w`` instead of
uniformly — after which the unchanged coverage machinery estimates the
weighted spread as ``(Σ w) · F_R(S)`` (Eq. 3 with the root distribution
swapped).

The draw must be O(1) per root, jit/shard_map-safe, and — crucially for the
repo's bit-parity contracts — *exactly* the historical uniform draw when no
weights are given.  A Walker alias table delivers all three: construction
is O(n) on the host, every draw is one gather + one compare, and the
one-uniform variant (bucket from ``floor(u·n)``, accept-vs-alias from the
fractional part) degenerates to ``min(floor(u·n), n-1)`` — byte-for-byte
the uniform refill draw — when every bucket has acceptance probability 1.

This module sits *below* the samplers (``rrset``/``dense``/``lt`` import
it); ``core/engine.py`` re-exports everything as the engine-facing surface.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class AliasTable(NamedTuple):
    """Walker alias table for O(1) weighted categorical draws on device.

    ``prob[i]`` is the acceptance probability of bucket i, ``alias[i]`` the
    fallback node.  A plain pytree of device arrays, so it passes straight
    through jit and shard_map.
    """
    prob: jnp.ndarray     # (n,) float32 in [0, 1]
    alias: jnp.ndarray    # (n,) int32


def build_alias_table(weights) -> AliasTable:
    """Host-side Walker alias construction (O(n)) from non-negative weights."""
    w = np.asarray(weights, np.float64)
    if w.ndim != 1:
        raise ValueError("root weights must be a 1-D vector")
    if (w < 0).any() or not np.isfinite(w).all() or w.sum() <= 0:
        raise ValueError("root weights must be non-negative, finite, and "
                         "not all zero")
    n = w.shape[0]
    p = w * (n / w.sum())
    prob = np.ones(n)
    alias = np.arange(n, dtype=np.int64)
    small = [i for i in range(n) if p[i] < 1.0]
    large = [i for i in range(n) if p[i] >= 1.0]
    while small and large:
        s, l = small.pop(), large.pop()
        prob[s] = p[s]
        alias[s] = l
        p[l] -= 1.0 - p[s]
        (small if p[l] < 1.0 else large).append(l)
    # numerical leftovers: both queues drain to probability-1 buckets
    for i in large + small:
        prob[i] = 1.0
        alias[i] = i
    return AliasTable(prob=jnp.asarray(prob, jnp.float32),
                      alias=jnp.asarray(alias, jnp.int32))


# One float32 uniform carries ~24 bits: splitting it into a bucket index
# AND an accept fraction is only sound while n << 2^24 (past that the
# fraction degenerates and the alias decision biases).  The one-uniform
# trick is therefore reserved for the refill worker's in-loop draw (which
# has exactly one spare uniform column) and guarded by this bound; the
# batch draw (:func:`draw_roots`) spends two draws and is exact at any n.
ONE_UNIFORM_MAX_N = 1 << 22


def roots_from_uniform(u, n: int, table: Optional[AliasTable] = None):
    """Map uniforms in [0, 1) to root ids — uniformly over ``[0, n)`` when
    ``table`` is None, else ∝ the table's weights via the one-uniform alias
    trick (``floor(u·n)`` picks the bucket, the fractional part decides
    accept-vs-alias; callers must keep ``n <= ONE_UNIFORM_MAX_N`` — see
    above).  With ``table=None`` this is *exactly* the historical
    ``min(floor(u·n), n-1)`` refill-root draw, keeping uniform sample
    streams bit-identical."""
    scaled = u * n
    idx = jnp.minimum(scaled.astype(jnp.int32), n - 1)
    if table is None:
        return idx
    frac = scaled - idx.astype(scaled.dtype)
    return jnp.where(frac < table.prob[idx], idx, table.alias[idx]).astype(
        jnp.int32)


def draw_roots(key, batch: int, n: int, table: Optional[AliasTable] = None):
    """Draw one batch of root ids — the shared root-sampling helper every
    engine routes through.  ``table=None`` is the historical uniform
    ``randint`` call (bit-identical streams for plain problems); with a
    table the roots come out ∝ its weights (one randint for the bucket +
    one uniform for the alias accept — exact at any n, unlike scaling a
    single float32 uniform), so Eq. 3's hit fraction estimates
    ``Σ_v w_v·P[v influenced] / Σ_v w_v``."""
    if table is None:
        return jax.random.randint(key, (batch,), 0, n, dtype=jnp.int32)
    ki, ka = jax.random.split(key)
    idx = jax.random.randint(ki, (batch,), 0, n, dtype=jnp.int32)
    accept = jax.random.uniform(ka, (batch,))
    return jnp.where(accept < table.prob[idx], idx, table.alias[idx]).astype(
        jnp.int32)

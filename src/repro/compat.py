"""Version shims for jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to the top-level
``jax`` namespace, and ``jax.lax.pvary`` only exists on newer releases (it is
only needed under the newer varying-types semantics, so the fallback is the
identity).  Import from here instead of hard-coding either location.
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.6
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401

_HAS_CHECK_REP = "check_rep" in inspect.signature(shard_map).parameters


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off where the flag exists.

    Older shard_map has no replication rule for ``while_loop`` bodies (the
    samplers) and needs ``check_rep=False``; newer jax renamed/retired the
    flag and handles while_loop natively, so there we pass nothing.
    """
    kw = {"check_rep": False} if _HAS_CHECK_REP else {}
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kw)


def pvary(x, axis_names):
    """``jax.lax.pvary`` when available, identity otherwise (pre-varying-types
    shard_map treats unvaried locals as already device-varying)."""
    fn = getattr(jax.lax, "pvary", None)
    if fn is None:
        return x
    return fn(x, (axis_names,) if isinstance(axis_names, str)
              else tuple(axis_names))

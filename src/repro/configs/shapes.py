"""Assigned input shapes per architecture family (see the task brief).

Every (arch × shape) cell resolves to a step kind + concrete input
ShapeDtypeStructs via the arch config's ``input_specs``.
"""
from __future__ import annotations

LM_SHAPES = {
    "train_4k":    dict(kind="train",   seq_len=4096,    global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768,   global_batch=32),
    "decode_32k":  dict(kind="decode",  seq_len=32768,   global_batch=128),
    "long_500k":   dict(kind="decode",  seq_len=524288,  global_batch=1),
}

GNN_SHAPES = {
    "full_graph_sm": dict(kind="train", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_classes=7),
    "minibatch_lg":  dict(kind="train", n_nodes=232965, n_edges=114615892,
                          batch_nodes=1024, fanout=(15, 10), d_feat=602,
                          n_classes=41),
    "ogb_products":  dict(kind="train", n_nodes=2449029, n_edges=61859140,
                          d_feat=100, n_classes=47),
    "molecule":      dict(kind="train", n_nodes=30, n_edges=64, batch=128,
                          d_feat=16, n_classes=2),
}

RECSYS_SHAPES = {
    "train_batch":    dict(kind="train", batch=65536),
    "serve_p99":      dict(kind="serve", batch=512),
    "serve_bulk":     dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1,
                           n_candidates=1_000_000, top_k=100),
}

# long_500k needs sub-quadratic context build-up: skipped for pure
# full-attention archs, run for the hybrid (gemma3 5:1 local:global) and the
# compressed-cache MLA arch (deepseek-v3).  See DESIGN.md §6.
LONG_CONTEXT_SKIPS = {"qwen2-0.5b", "olmo-1b", "llama4-scout-17b-a16e"}


def cells():
    """All (arch_id, shape_id) dry-run cells (with justified skips removed)."""
    from repro.configs.registry import ARCHS
    out = []
    for arch_id, meta in ARCHS.items():
        for shape_id in meta["shapes"]:
            if shape_id == "long_500k" and arch_id in LONG_CONTEXT_SKIPS:
                continue
            out.append((arch_id, shape_id))
    return out

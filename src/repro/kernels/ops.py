"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True everywhere in this repo (CPU container); on a
real TPU deployment set ``repro.kernels.ops.INTERPRET = False`` (or pass
explicitly) and the same BlockSpecs compile to Mosaic.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import membership as _membership
from repro.kernels import bernoulli as _bernoulli
from repro.kernels import bitset as _bitset

INTERPRET = True


def membership_rows(rows, lengths, u, *, block_rows: int = 256,
                    interpret: bool | None = None):
    return _membership.membership_rows(
        rows, lengths, u, block_rows=block_rows,
        interpret=INTERPRET if interpret is None else interpret)


def bernoulli_edges(weights, seed, *, block: int = 1024,
                    interpret: bool | None = None):
    return _bernoulli.bernoulli_edges(
        weights, seed, block=block,
        interpret=INTERPRET if interpret is None else interpret)


def pack_bits(bits, *, interpret: bool | None = None):
    return _bitset.pack_bits(
        bits, interpret=INTERPRET if interpret is None else interpret)


def bitset_or(a, b, *, interpret: bool | None = None):
    return _bitset.bitset_or(
        a, b, interpret=INTERPRET if interpret is None else interpret)


def bitset_andnot(a, b, *, interpret: bool | None = None):
    return _bitset.bitset_andnot(
        a, b, interpret=INTERPRET if interpret is None else interpret)


def popcount_words(words, *, interpret: bool | None = None):
    return _bitset.popcount_words(
        words, interpret=INTERPRET if interpret is None else interpret)


def occur_from_bitset(words, *, interpret: bool | None = None):
    return _bitset.occur_from_bitset(
        words, interpret=INTERPRET if interpret is None else interpret)


def flash_attention(q, k, v, *, causal=True, bq=128, bk=128,
                    interpret: bool | None = None):
    from repro.kernels import flashattn as _fa
    return _fa.flash_attention(
        q, k, v, causal=causal, bq=bq, bk=bk,
        interpret=INTERPRET if interpret is None else interpret)

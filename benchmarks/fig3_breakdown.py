"""Paper Fig. 3: runtime breakdown — RR sampling vs. seed selection.

The paper's observation: IMM is sampling-dominated; gIM flips the balance
because sampling accelerates more than selection.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ba_graph, write_csv, report
from repro.core.imm import IMMSolver
from repro.core import coverage as cov
from repro.core import oracle
from repro.graph import csr as csr_mod

K, EPS, N, R = 10, 0.4, 8000, 6


def main():
    g = ba_graph(N, R)
    g_rev = csr_mod.reverse(g)
    # --- serial oracle breakdown
    offs = np.asarray(g_rev.offsets); idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    rr = [oracle.rr_set_ic(offs, idx, w, int(rng.integers(N)), rng)
          for _ in range(4096)]
    t_sample_o = time.perf_counter() - t0
    t0 = time.perf_counter()
    oracle.greedy_max_coverage(rr, N, K)
    t_select_o = time.perf_counter() - t0
    # --- gIM-JAX breakdown (same θ)
    solver = IMMSolver(g, engine="queue", batch=512, seed=0)
    t0 = time.perf_counter()
    solver.sample_until(4096)
    t_sample_j = time.perf_counter() - t0
    store = solver._store()
    t0 = time.perf_counter()
    cov.select_seeds(store, K)
    t_select_j = time.perf_counter() - t0
    rows = [
        ["imm_oracle", round(t_sample_o, 3), round(t_select_o, 3),
         round(100 * t_sample_o / (t_sample_o + t_select_o), 1)],
        ["gim_queue", round(t_sample_j, 3), round(t_select_j, 3),
         round(100 * t_sample_j / (t_sample_j + t_select_j), 1)],
    ]
    write_csv("fig3_breakdown",
              ["solver", "t_sampling_s", "t_selection_s", "sampling_pct"],
              rows)
    for r_ in rows:
        report(f"fig3/{r_[0]}", (r_[1] + r_[2]) * 1e6,
               f"sampling_pct={r_[3]}")


if __name__ == "__main__":
    main()

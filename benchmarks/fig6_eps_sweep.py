"""Paper Fig. 6: runtime vs. ε (θ is inverse-quadratic in ε — §4.5)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ba_graph, write_csv, report
from repro.core.imm import imm
from repro.core import oracle
from repro.graph import csr as csr_mod

N, R, K = 6000, 6, 10


def main():
    g = ba_graph(N, R)
    g_rev = csr_mod.reverse(g)
    offs = np.asarray(g_rev.offsets); idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    rows = []
    for eps in (0.5, 0.4, 0.3, 0.25):
        t0 = time.perf_counter()
        _, _, theta = oracle.imm_oracle(offs, idx, w, N, K, eps, seed=0)
        t_o = time.perf_counter() - t0
        t0 = time.perf_counter()
        _, _, st = imm(g, K, eps, engine="queue", batch=512, seed=0)
        t_j = time.perf_counter() - t0
        rows.append([eps, theta, st.theta, round(t_o, 3), round(t_j, 3),
                     round(t_o / t_j, 2)])
        report(f"fig6/eps={eps}", t_j * 1e6,
               f"theta={st.theta};speedup={t_o / t_j:.2f}x")
    write_csv("fig6_eps_sweep", ["eps", "theta_oracle", "theta_gim",
                                 "t_imm_s", "t_gim_s", "speedup"], rows)


if __name__ == "__main__":
    main()

"""IMM driver (paper Alg. 2 + θ sampling + seed selection), engine-agnostic.

The host orchestrates rounds of RR batches (exactly like gIM's persistent
N_b-block kernel relaunches, Alg. 6) against any registered
:class:`~repro.core.engine.SamplerEngine` — ``queue`` (gIM-faithful),
``dense`` (frontier-SpMV), ``refill`` (persistent lanes), ``lt`` (LT walks),
or a caller-supplied engine instance (e.g. the sharded launcher's).  Every
round is ``batch = engine.sample(key)`` → ``store.append_batch(batch)``; the
solver never inspects engine internals.

**One entry point, five problems** (DESIGN.md §6): the solver is driven by a
declarative :class:`~repro.core.problem.IMProblem` —

    IMMSolver(g).solve(IMProblem(k=10, eps=0.3))                  # plain
    IMMSolver(g).solve(IMProblem(k=10, eps=0.3, node_weights=w))  # weighted
    IMMSolver(g).solve(IMProblem(eps=0.3, costs=c, budget=B))     # budgeted
    IMMSolver(g).solve(IMProblem(k=10, eps=0.3, candidates=ids))  # targeted
    IMMSolver(g).solve(IMProblem(k=3, t_rounds=4, theta=4096))    # MRIM

returning a typed :class:`~repro.core.problem.IMResult` (seeds, spread on
the problem's scale, per-seed marginal gains, stats).  Plain problems take
exactly the historical code paths — same RNG streams, same selection
programs — so their seeds/gains/F_R are bit-identical to the historical
``solve(k, eps)`` form (removed after its deprecation window; DESIGN.md §6
has the migration notes).

Variants thread through every layer: weighted problems draw roots ∝
``node_weights`` through the engines' shared alias table
(:func:`~repro.core.engine.draw_roots`; engines without weighted-root
support fall back to the importance-weighted row estimator on a
``row_weighted`` store), and non-plain selection runs the generalized
shard_map scan (:func:`~repro.core.coverage.select_variant` /
``select_seeds_celf(spec=...)``) with candidate masks, cost-ratio lazy
greedy and per-round (group) budgets — on a mesh of any size, under the
same transfer guard.

The hot loop is *mesh-resident*: the RR pool is a
:class:`~repro.core.coverage.ShardedDeviceRRStore` sharded over the device
mesh chosen once at solver construction (``mesh=`` — ``None`` is the
1-device mesh, the same code path), selection is the capacity-stable
psum-reduced greedy, and for engines that declare ``device_resident``
the whole sampling+selection loop runs under
``jax.transfer_guard("disallow")`` on a mesh of any size.  The only
host↔device traffic per round is the store's explicit per-shard count
fetch — the same per-relaunch ``N_RR`` readback gIM's Alg. 6 host loop
performs.

All martingale math (λ', λ*, the Alg. 2 LB loop) follows IMM [Tang et al.'15]
and is shared with the numpy oracle (core/oracle.py) so both sides compute
identical θ schedules.  For non-plain variants the schedule is reused with
the spread scale swapped in (``Σw`` for weighted problems) — a heuristic
extension; the selection itself stays exact greedy on the sampled pool.
"""
from __future__ import annotations

import math
import time
from dataclasses import asdict, dataclass, field
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph, reverse
from repro.ckpt import checkpoint as ckpt_mod
from repro.core import coverage as cov
from repro.core import sketch as sketch_mod
from repro.core.oracle import imm_theta_params
from repro.core.problem import (IMProblem, IMResult, ResolvedProblem,
                                problem_from_state, problem_state)
from repro.core.engine import (FusedSketchEngine, SamplerEngine, make_engine,
                               resolve_engine_name, split_key as _split_key)
from repro.ft.failures import DeadlineExceeded, FaultPolicy


@jax.jit
def _accum_round_stats(steps_acc, ovf_acc, steps, overflowed):
    """Device-scalar stat accumulation — replaces the per-round blocking
    ``int(batch.steps)`` / ``np.asarray(batch.overflowed)`` syncs."""
    return (steps_acc + steps.astype(jnp.int32),
            ovf_acc + overflowed.sum(dtype=jnp.int32))


@jax.jit
def _gather_row_weights(w_dev, roots):
    """Row weight of each batch row: its root's node weight (the
    importance-weighted fallback estimator)."""
    return w_dev[jnp.clip(roots.astype(jnp.int32), 0, w_dev.shape[0] - 1)]


@dataclass
class IMMStats:
    theta: int = 0
    n_rr_sampled: int = 0
    lb: float = 1.0
    lb_iters: int = 0
    rounds: int = 0
    overflow_fraction: float = 0.0
    frac_covered: float = 0.0
    sampling_steps: int = 0
    selection: str = "auto"
    variant: str = "plain"
    early_exit_skips: int = 0
    budget_spent: float = 0.0
    mesh_shape: tuple = (1,)
    pool_sharding: str = "samples:1"
    per_device_pool_bytes: int = 0
    # resume watermark for the Alg. 2 LB loop: index of the last LB
    # iteration that finished *without* breaking.  A restored solve skips
    # iterations <= lb_completed instead of re-running them over the (now
    # larger) pool, which would shift est/break points (DESIGN.md §8).
    lb_completed: int = 0
    history: list = field(default_factory=list)


@dataclass
class PoolLease:
    """Explicit ownership of a prepared solver's sampled state.

    ``IMMSolver.export_pool()`` detaches the RR pool — plus everything that
    makes it *resumable*: the signature-defining problem, the RNG cursor,
    and the stat accumulators — and hands it to the caller;
    ``adopt_pool(lease)`` installs it into a (same-graph, same-options)
    solver, which then continues bit-identically to the exporter.  The
    serving registry (``repro.serve``) uses this to own pool memory
    outside any solver: an evicted lease is *the* reference to the device
    buffers, so dropping it frees them accountably.
    """
    problem: IMProblem                 # pool-signature template
    store: "cov.ShardedDeviceRRStore"
    key: jax.Array                     # RNG cursor (sampling resumes here)
    stats: IMMStats
    steps_acc: jax.Array
    ovf_acc: jax.Array
    ovf_lanes: int
    # signature_digest of an eps-driven solve that was interrupted
    # mid-flight (None when no solve is in progress): the adopting solver
    # resumes that solve's LB loop from stats.lb_completed instead of
    # restarting it
    active_solve: Optional[str] = None

    def pool_bytes(self) -> int:
        s = self.store
        return s.n_shards * (s.per_device_pool_bytes() + s.sketch_bytes())


# user-facing selection knob -> DeviceRRStore.select method.  "fused" is the
# single-scan flat path (the historical default), "bitset" the Pallas
# bit-matrix path, "celf-sketch" the lazy greedy over coverage sketches.
_SELECTION_METHODS = {
    "auto": "auto", "fused": "flat", "flat": "flat", "bitset": "bitset",
    "celf-sketch": "celf", "celf": "celf",
}


class IMMSolver:
    """Stateful solver: owns the RR pool so Alg. 2 reuses earlier samples.

    ``engine`` is a registered engine name or a ready ``SamplerEngine``
    instance; ``batch``/``qcap``/``ec`` are forwarded to the engine's config
    (each engine takes the subset it understands).  ``model="lt"`` keeps its
    historical meaning by resolving to the ``lt`` engine (a problem's
    ``model=`` field overrides it per solve).

    The engine and the pool are rebuilt whenever a solve's problem changes
    their *signature* (diffusion model, ``t_rounds``, ``node_weights``) —
    repeated solves of same-signature problems keep reusing the pool, like
    the historical solver did.
    """

    def __init__(self, g: CSRGraph, *,
                 engine: Union[str, SamplerEngine] = "queue",
                 batch: Optional[int] = None, qcap: Optional[int] = None,
                 ec: Optional[int] = None, model: Optional[str] = None,
                 selection: str = "auto", sketch_k: Optional[int] = None,
                 eval_batch: Optional[int] = None,
                 mesh=None, seed: int = 0,
                 fault_policy: Optional[FaultPolicy] = None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0, checkpoint_keep: int = 3):
        self.g = g
        self.n = g.n_nodes
        self._engine_arg = engine
        self._engine_opts = dict(batch=batch, qcap=qcap, ec=ec)
        self._model_arg = model
        # fault tolerance (DESIGN.md §8): the policy wraps every hot-loop
        # boundary (sample/append/grow/select) in retry-with-backoff;
        # checkpoint_dir + checkpoint_every>0 turn on periodic durable pool
        # saves every N sampling rounds (auto-resume is the caller's
        # restore_pool call — see launch/im_solve.py)
        self.fault_policy = fault_policy
        self._ckpt_dir = checkpoint_dir
        self._ckpt_every = int(checkpoint_every)
        self._ckpt_keep = int(checkpoint_keep)
        self._last_ckpt_round = 0
        self._active_solve: Optional[str] = None
        if isinstance(engine, str):
            self.g_rev = reverse(g)
        else:
            # engine instance passed in: it owns its graph + configuration,
            # so sampling options on the solver would be silently ignored
            if any(v is not None for v in (batch, qcap, ec, model)):
                raise ValueError(
                    "batch/qcap/ec/model have no effect when an engine "
                    "instance is passed; configure the engine instead")
            self.g_rev = getattr(engine, "g_rev", None)
        if selection not in _SELECTION_METHODS:
            raise ValueError(f"unknown selection {selection!r}; one of "
                             f"{sorted(_SELECTION_METHODS)}")
        self.selection = selection
        self._sel_method = _SELECTION_METHODS[selection]
        self._sketch_k_arg = sketch_k
        # CELF exact-verification batch width (celf/celf-sketch selection):
        # candidates re-evaluated exactly per device pass.  None keeps the
        # backend default; benchmarks/perf_im_engines --selection-only
        # sweeps it (BENCH_selection.json)
        if eval_batch is not None and int(eval_batch) < 1:
            raise ValueError("eval_batch must be >= 1")
        self.eval_batch = None if eval_batch is None else int(eval_batch)
        self._mesh = mesh
        self.key = jax.random.key(seed)
        self._engine_obj = None
        self._store_obj = None
        self._sig = None
        self._sig_problem = None
        self._row_weight_mode = False
        self._node_w_dev = None
        # selection-side certificate of the last approximate-mode (pool-
        # free) solve: lo/hi covered-row bounds, saturation, rel. error
        self._sketch_info = None
        if isinstance(engine, str):
            if engine == "mrim":
                # fail fast like the historical API: the tagged engine's
                # item space is n*t_rounds, not the graph's n nodes — MRIM
                # goes through IMProblem(t_rounds=...), which picks the
                # engine itself
                raise ValueError(
                    "engine 'mrim' samples a tagged item space, not the "
                    "graph's nodes; set t_rounds= on the IMProblem instead "
                    "(the solver resolves the mrim engine per problem)")
            # eager default build: construction happens *outside* any
            # caller transfer guard, so the graph uploads land here — a
            # first solve with a different signature (weights/t_rounds)
            # rebuilds once via prepare(), which callers holding an outer
            # guard invoke explicitly before entering it
            self._prepare(IMProblem(k=1, eps=0.5,
                                    model=self._default_model()))
        elif (engine.item_space == self.n
              and getattr(engine, "root_weights", None) is None):
            # engine instance on the plain node space: build eagerly —
            # cheap (the instance is reused) and keeps `solver.engine is
            # eng` true right after construction
            self._prepare(IMProblem(k=1, eps=0.5,
                                    model=self._default_model()))
        # a tagged (item_space != n) or weighted-root engine INSTANCE
        # defers instead — its first solve must carry the matching
        # t_rounds / node_weights (callers holding an outer transfer guard
        # call prepare(problem) explicitly first)

    def _default_model(self) -> str:
        return "lt" if self._model_arg == "lt" else "ic"

    def _ensure_prepared(self):
        if self._sig is None:
            self._prepare(IMProblem(k=1, eps=0.5,
                                    model=self._default_model()))

    @property
    def engine(self):
        self._ensure_prepared()
        return self._engine_obj

    @property
    def store(self) -> "cov.ShardedDeviceRRStore":
        self._ensure_prepared()
        return self._store_obj

    # -- problem-driven engine/store lifecycle ------------------------------
    def prepare(self, problem: IMProblem) -> ResolvedProblem:
        """Pre-build the engine + pool for ``problem`` (idempotent per
        signature).  ``solve(problem)`` calls this itself; call it
        explicitly to do the host-side construction (reverse graph, alias
        table, device placement) *before* entering an outer
        ``jax.transfer_guard("disallow")`` region."""
        return self._prepare(problem)

    def _prepare(self, problem: IMProblem,
                 _store: "cov.ShardedDeviceRRStore | None" = None
                 ) -> ResolvedProblem:
        r = problem.resolve(self.n)
        # the constructor's model= survives as the default for problems that
        # don't set one (IMProblem.model=None); an explicit model on the
        # problem — including "ic" — always wins
        model = problem.model or self._default_model()
        if problem.t_rounds is not None and model == "lt":
            raise ValueError("MRIM sampling is IC-only (paper §4.8); the "
                             "solver's default model is 'lt'")
        w = r.node_weights
        # the celf path estimates from the incremental coverage sketch, and
        # the θ early-exit gate reads it (an incremental fold is required:
        # its global row numbering keeps the occupancy==count identity on
        # any mesh — the on-demand per-shard fold does not)
        sketch_k = self._sketch_k_arg
        if sketch_k is None and (self._sel_method == "celf"
                                 or problem.early_exit):
            sketch_k = cov.ShardedDeviceRRStore.DEFAULT_SKETCH_K
        # approximate (pool-free) mode: the sketch IS the pool, so one
        # always exists, auto-sized from (ε, n) so the certified estimator
        # error stays within ε/2 at design load (core/sketch.auto_sketch_k)
        approx = problem.mode == "approximate"
        if approx and sketch_k is None:
            sketch_k = sketch_mod.auto_sketch_k(problem.eps, self.n)
        # engine/pool lifecycle is keyed on the problem's canonical pool
        # signature (content hash of model/t_rounds/node_weights — see
        # IMProblem.pool_digest): problems differing only in weight *values*
        # can never alias one pool, unlike the old hash(tobytes) tuple key
        if isinstance(self._engine_arg, str):
            name = ("mrim" if problem.t_rounds is not None
                    else resolve_engine_name(self._engine_arg, model))
            sig = ("name", name, problem.pool_digest(model=model), sketch_k)
        else:
            sig = ("inst", id(self._engine_arg), problem.pool_digest(),
                   sketch_k)
        if sig == self._sig:
            return r
        # (re)build engine + pool for this problem signature
        row_weight_mode = False
        if isinstance(self._engine_arg, str):
            opts = dict(self._engine_opts)
            if problem.t_rounds is not None:
                opts["t_rounds"] = problem.t_rounds
            engine = make_engine(name, self.g_rev, root_weights=w, **opts)
        else:
            engine = self._engine_arg
            eng_w = getattr(engine, "root_weights", None)
            if w is None and eng_w is not None:
                # converse mismatch: the engine samples roots ∝ its own
                # weights, so a plain solve on it would silently return the
                # weighted objective on the uniform scale — a wrong number
                raise ValueError(
                    "engine instance draws weighted roots (root_weights "
                    "set) but the problem has no node_weights; set "
                    "node_weights on the IMProblem (or use an unweighted "
                    "engine)")
            if w is not None and not (
                    eng_w is not None
                    and np.array_equal(np.asarray(eng_w, np.float32), w)):
                # instance without matching weighted-root sampling: fall
                # back to the importance-weighted row estimator (uniform
                # roots, rows weighted by node_weights[root])
                row_weight_mode = True
        if engine.item_space != r.n_items:
            raise ValueError(
                f"engine {getattr(engine, 'name', '?')!r} samples an "
                f"item space of {engine.item_space}, not the problem's "
                f"{r.n_items} items; tagged engines need a matching "
                f"t_rounds= on the IMProblem")
        if approx:
            # same sampler, same RNG stream — only the batch *destination*
            # changes: appends fold into the pool-free sketch store below
            engine = FusedSketchEngine(engine)
        self._engine_obj = engine
        self.engine_name = getattr(engine, "name", type(engine).__name__)
        self._row_weight_mode = row_weight_mode
        self._node_w_dev = (jax.device_put(w) if row_weight_mode else None)
        # mesh placement is decided exactly once, here: the pool, the
        # sketch, and every selection backend live on this mesh for the
        # solver's lifetime (mesh=None -> the 1-device mesh special case)
        if _store is not None:                   # adopt_pool() hand-off
            want_k = (sketch_mod.resolve_sketch_k(sketch_k)
                      if sketch_k is not None else None)
            if getattr(_store, "pool_free", False) != approx:
                raise ValueError(
                    "adopted pool kind does not match the problem mode: a "
                    "pool-free sketch store can only back mode='approximate'"
                    " solves, and an exact pool only exact ones")
            if (_store.n_nodes != engine.item_space
                    or _store.row_weighted != row_weight_mode
                    or _store.sketch_k != want_k):
                raise ValueError(
                    "adopted pool does not match the problem signature: "
                    f"store (n={_store.n_nodes}, row_weighted="
                    f"{_store.row_weighted}, sketch_k={_store.sketch_k}) "
                    f"vs engine (n={engine.item_space}, row_weighted="
                    f"{row_weight_mode}, sketch_k={want_k})")
            if self._mesh is not None and _store.mesh != self._mesh:
                raise ValueError("adopted pool lives on a different mesh "
                                 "than the solver's mesh= argument")
            self._store_obj = _store
        elif approx:
            # pool-free: the flat pool / ids / valid buffers are never
            # allocated — frontier batches fold straight into the packed
            # sketch words (the DiFuseR-mode memory model, DESIGN.md §10)
            self._store_obj = cov.SketchRRStore(
                engine.item_space, sketch_k=sketch_k, mesh=self._mesh)
        else:
            self._store_obj = cov.ShardedDeviceRRStore(
                engine.item_space, sketch_k=sketch_k, mesh=self._mesh,
                row_weighted=row_weight_mode)
        if self.fault_policy is not None:
            # gate pool growth through the policy's "grow" site, so an
            # injected (or real) allocation failure surfaces *before* any
            # buffer is re-allocated and the append stays retryable
            pol = self.fault_policy
            self._store_obj.alloc_check = (
                lambda store, newcap: pol.check(
                    "grow", {"newcap": newcap,
                             "bytes": newcap * store.n_shards * 9}))
        self._sig = sig
        self._sig_problem = problem
        store = self._store_obj
        self._stats = IMMStats(
            selection=self.selection,
            variant=problem.variant,
            mesh_shape=tuple(int(s) for s in store.mesh.devices.shape),
            pool_sharding=f"{store.axis}:{store.n_shards}")
        self._stats_dirty = False
        # stats accumulate as device scalars; materialized once per
        # sample_until / on `stats` access, not per round
        self._steps_acc = jnp.zeros((), jnp.int32)
        self._ovf_acc = jnp.zeros((), jnp.int32)
        self._ovf_lanes = 0
        # engines advertising full device residency let the solver hold a
        # transfer guard over the whole hot loop; host-path engines (e.g.
        # third-party adapters) fall back to unguarded execution
        self._guard = ("disallow"
                       if getattr(engine, "device_resident", False)
                       else "allow")
        self._sample = getattr(engine, "sample_device", engine.sample)
        # a sharded engine on the *same* mesh hands the store rows that are
        # already resident on their sampling device — no dev0 gather
        if (store.n_shards > 1
                and getattr(engine, "mesh", None) == store.mesh
                and hasattr(engine, "sample_sharded")):
            self._sample = engine.sample_sharded
        return r

    # -- pool ownership (serving registry lifecycle) -----------------------
    def pool_bytes(self) -> int:
        """Total live device bytes of the solver's pool + sketch across all
        shards (0 when unprepared) — the serving registry's memory-budget
        accounting unit."""
        if self._store_obj is None:
            return 0
        s = self._store_obj
        return s.n_shards * (s.per_device_pool_bytes() + s.sketch_bytes())

    def export_pool(self) -> PoolLease:
        """Transfer ownership of the prepared pool *out* of the solver.

        Returns a :class:`PoolLease` holding the store, the RNG cursor and
        the stat accumulators; the solver reverts to the unprepared state
        (its next solve builds a fresh pool).  The lease is the only
        remaining reference to the device buffers — dropping it frees
        them; handing it to :meth:`adopt_pool` on a same-graph solver
        resumes sampling/selection bit-identically to this solver.
        """
        if self._sig is None:
            raise RuntimeError("export_pool() needs a prepared solver — "
                               "nothing to export")
        self._materialize_stats()
        lease = PoolLease(
            problem=self._sig_problem, store=self._store_obj, key=self.key,
            stats=self._stats, steps_acc=self._steps_acc,
            ovf_acc=self._ovf_acc, ovf_lanes=self._ovf_lanes,
            active_solve=self._active_solve)
        self._store_obj = None
        self._engine_obj = None
        self._sig = None
        self._sig_problem = None
        self._active_solve = None
        return lease

    def drop_pool(self) -> int:
        """Discard the prepared pool *without* exporting it; returns the
        bytes dropped.  This is the quarantine path (DESIGN.md §8): after a
        solve died mid-flight the device buffers may be ahead of the host
        mirrors (partially-appended pool), so the state must neither serve
        nor be checkpointed — it is simply dereferenced.  No-op on an
        unprepared solver."""
        freed = self.pool_bytes()
        self._store_obj = None
        self._engine_obj = None
        self._sig = None
        self._sig_problem = None
        self._active_solve = None
        return freed

    def adopt_pool(self, lease: PoolLease) -> None:
        """Install an exported pool (same graph, matching signature/options)
        and resume from the lease's RNG cursor and stats."""
        self._sig = None                       # force the rebuild path
        self._prepare(lease.problem, _store=lease.store)
        self.key = lease.key
        self._stats = lease.stats
        self._steps_acc = lease.steps_acc
        self._ovf_acc = lease.ovf_acc
        self._ovf_lanes = lease.ovf_lanes
        self._active_solve = lease.active_solve
        self._stats_dirty = True

    # -- durable pool checkpoints (DESIGN.md §8) ---------------------------
    POOL_CKPT_FORMAT = "im-pool"
    POOL_CKPT_VERSION = 1
    # v2 sub-kind: pool-free (mode="approximate") checkpoints carry only
    # the sketch words + row counters + RNG cursor; the store config's
    # "kind" field dispatches the restore class
    POOL_CKPT_VERSION_SKETCH = 2

    def save_pool(self, ckpt_dir: str, *, keep: Optional[int] = None) -> str:
        """Write the prepared pool as a durable checkpoint: sharded store
        buffers + exact host mirrors, RNG cursor, stat accumulators, and
        the signature problem — everything a fresh process needs to resume
        sampling bit-identically via :meth:`restore_pool`.  Atomic (tmpdir
        + rename, via ``repro.ckpt.checkpoint``), rotated to ``keep``
        checkpoints; the step number is the sampling round count."""
        self._ensure_prepared()
        self._materialize_stats()
        state = dict(self.store.state())
        state["rng_key"] = np.asarray(
            jax.device_get(jax.random.key_data(self.key)))
        state["steps_acc"] = np.asarray(jax.device_get(self._steps_acc))
        state["ovf_acc"] = np.asarray(jax.device_get(self._ovf_acc))
        st = asdict(self._stats)
        st["mesh_shape"] = list(st["mesh_shape"])
        st["history"] = [list(h) for h in st["history"]]
        meta = {
            "format": self.POOL_CKPT_FORMAT,
            "version": (self.POOL_CKPT_VERSION_SKETCH
                        if getattr(self.store, "pool_free", False)
                        else self.POOL_CKPT_VERSION),
            "store": self.store.config(),
            "problem": problem_state(self._sig_problem),
            "stats": st,
            "ovf_lanes": int(self._ovf_lanes),
            "active_solve": self._active_solve,
        }
        return ckpt_mod.save(ckpt_dir, self._stats.rounds, state,
                             keep=self._ckpt_keep if keep is None else keep,
                             meta=meta)

    def restore_pool(self, ckpt_dir: str, *, step: Optional[int] = None
                     ) -> int:
        """Rebuild the pool from a :meth:`save_pool` checkpoint (latest step
        unless ``step=``) and adopt it: subsequent ``sample_until`` rounds
        continue from the saved RNG cursor against the saved buffers,
        bit-identically to the process that wrote the checkpoint.  The
        solver must be configured with the same options and a same-size
        mesh; returns the restored step."""
        if step is None:
            step = ckpt_mod.latest_step(ckpt_dir)
            if step is None:
                raise FileNotFoundError(
                    f"no pool checkpoint under {ckpt_dir!r}")
        meta = ckpt_mod.load_manifest(ckpt_dir, step)["meta"]
        if meta.get("format") != self.POOL_CKPT_FORMAT:
            raise ValueError(f"{ckpt_dir!r} step {step} is not an im-pool "
                             f"checkpoint (format={meta.get('format')!r})")
        if meta.get("version") not in (self.POOL_CKPT_VERSION,
                                       self.POOL_CKPT_VERSION_SKETCH):
            raise ValueError(
                f"pool checkpoint version {meta.get('version')} not "
                f"supported (want {self.POOL_CKPT_VERSION} or "
                f"{self.POOL_CKPT_VERSION_SKETCH})")
        items = {k.strip("[]'\""): v
                 for k, v in ckpt_mod.restore_items(ckpt_dir, step).items()}
        kind = meta["store"].get("kind", "sharded")
        store_cls = (cov.SketchRRStore if kind == "sketch"
                     else cov.ShardedDeviceRRStore)
        store = store_cls.from_state(items, meta["store"], mesh=self._mesh)
        st = dict(meta["stats"])
        st["mesh_shape"] = tuple(st["mesh_shape"])
        st["history"] = [tuple(h) for h in st["history"]]
        # explicit device_puts: the whole restore is legal under an outer
        # jax.transfer_guard("disallow")
        lease = PoolLease(
            problem=problem_from_state(meta["problem"]), store=store,
            key=jax.random.wrap_key_data(
                jax.device_put(np.asarray(items["rng_key"]))),
            stats=IMMStats(**st),
            steps_acc=jax.device_put(np.asarray(items["steps_acc"])),
            ovf_acc=jax.device_put(np.asarray(items["ovf_acc"])),
            ovf_lanes=int(meta["ovf_lanes"]),
            active_solve=meta.get("active_solve"))
        self.adopt_pool(lease)
        self._last_ckpt_round = self._stats.rounds
        return int(step)

    # -- stats -------------------------------------------------------------
    @property
    def stats(self) -> IMMStats:
        self._ensure_prepared()
        self._materialize_stats()
        return self._stats

    def _materialize_stats(self):
        if self._stats_dirty:
            steps, ovf = (int(x) for x in jax.device_get(
                (self._steps_acc, self._ovf_acc)))
            st = self._stats
            st.sampling_steps = steps
            st.n_rr_sampled = self.store.n_rr
            st.overflow_fraction = (ovf / self._ovf_lanes
                                    if self._ovf_lanes else 0.0)
            st.per_device_pool_bytes = self.store.per_device_pool_bytes()
            self._stats_dirty = False

    # -- sampling ----------------------------------------------------------
    def _round(self):
        """One sampling round, *transactional* w.r.t. the RNG cursor: the
        split key is committed only after the batch has landed in the
        store, so a failed (and policy-retried) round replays the exact
        same subkey against unchanged buffers — the fault-free and
        retried streams stay bit-identical (DESIGN.md §8)."""
        self._ensure_prepared()
        pol = self.fault_policy
        timer = pol.round_timer if pol is not None else None
        if timer is not None:
            timer.start()
        new_key, sub = _split_key(self.key)
        batch = (pol.run(lambda: self._sample(sub), "sample")
                 if pol is not None else self._sample(sub))

        def _append():
            if self._row_weight_mode:
                if batch.roots is None:
                    raise ValueError(
                        "weighted problem on an engine that neither supports "
                        "root_weights nor reports batch roots — cannot form "
                        "the importance-weighted estimator")
                self.store.append_batch(
                    batch, row_w=_gather_row_weights(self._node_w_dev,
                                                     batch.roots))
            else:
                self.store.append_batch(batch)

        if pol is not None:
            pol.run(_append, "append")
        else:
            _append()
        self.key = new_key       # commit the cursor: the round is durable
        self._steps_acc, self._ovf_acc = _accum_round_stats(
            self._steps_acc, self._ovf_acc, batch.steps, batch.overflowed)
        self._ovf_lanes += int(np.prod(batch.overflowed.shape))
        self._stats.rounds += 1
        self._stats_dirty = True
        if timer is not None:
            dt = timer.stop()
            if timer.is_straggler(dt):
                pol.straggler_rounds += 1

    def sample_until(self, theta: int):
        # the loop condition reads the store's exact host-mirrored row count
        # (explicit scalar fetch per append — gIM's Alg. 6 N_RR readback);
        # no pool data crosses to the host.  A restored solver re-enters
        # here with n_rr already at the saved watermark and simply tops up.
        while self.store.n_rr < theta:
            self._round()
            if (self._ckpt_dir and self._ckpt_every > 0
                    and self._stats.rounds - self._last_ckpt_round
                    >= self._ckpt_every):
                self.save_pool(self._ckpt_dir)
                self._last_ckpt_round = self._stats.rounds
        self._materialize_stats()

    def _store(self) -> cov.RRStore:
        return self.store.snapshot()

    # -- variant plumbing --------------------------------------------------
    def _selection_spec(self, r: ResolvedProblem):
        """None for plain problems (the bit-identical fast paths); a
        :class:`~repro.core.coverage.SelectionSpec` otherwise.  A weighted
        problem whose engine samples roots ∝ w needs *no* selection change
        (rows are equi-weighted by construction), so weights alone only
        force a spec in row-weight fallback mode."""
        p = r.problem
        if p.is_plain and not self._row_weight_mode:
            return None
        if (p.node_weights is not None and not self._row_weight_mode
                and p.budget is None and p.candidates is None
                and p.t_rounds is None):
            return None
        if p.t_rounds is not None:
            n_group, n_groups, quota = r.n_nodes, r.t_rounds, p.k
        else:
            n_group, n_groups, quota = r.n_items, 1, r.k_steps
        costs = None
        if r.costs is not None:
            costs = np.tile(r.costs, r.t_rounds)
        return cov.SelectionSpec(
            k_steps=r.k_steps, n_group=n_group, n_groups=n_groups,
            group_quota=quota, cand=r.cand_mask_items, costs=costs,
            budget=p.budget, weighted=self._row_weight_mode)

    def _early_exit_skip(self, r: ResolvedProblem, threshold: float) -> bool:
        """Sketch-driven θ early exit (Alg. 2 LB gate): skip the exact
        selection of one LB iteration when even an *upper bound* on the
        achievable coverage cannot pass the ``est >= threshold`` check.

        The bound is linear counting over the per-item sketch occupancy
        (one mesh-parallel popcount sweep).  It is only applied in the
        exact-safe regime ``n_rr <= sketch_k`` with ``"mod"`` bucketing,
        where occupancy == exact per-item row count and linear counting can
        only round *up* — so ``Σ top-k LC(occ) >= coverage of any k seeds``
        and skipping provably never changes the loop's outcome (the exact
        est would have failed the check too).  Weighted/budgeted problems
        skip the gate (their objective is not a row count).
        """
        p = r.problem
        st = self.store
        if (not p.early_exit or st.sketch_k is None
                or st.sketch_mode != "mod" or self._row_weight_mode
                or r.node_weights is not None or p.budget is not None):
            return False
        n_rr = st.n_rr
        if n_rr == 0 or n_rr > st.sketch_k:
            return False
        fns = cov._mesh_select_fns(st.mesh)
        empty = jax.device_put(
            np.zeros((st.n_shards, st.sketch_k // 32), np.uint32),
            st._sh_buf)
        occ = np.asarray(jax.device_get(fns.sweep(
            st.sketch_words_mesh(), empty,
            stripe=st.sketch_rows // st.n_shards)))[:r.n_items]
        counts = sketch_mod.linear_count(occ, st.sketch_k)
        mask = r.cand_mask_items
        if mask is not None:
            counts = counts[mask]
        top = float(np.sort(counts)[::-1][:r.k_steps].sum())
        est_ub = r.scale * min(float(n_rr), top) / max(n_rr, 1)
        return est_ub < threshold

    def _approx_bounds(self, r: ResolvedProblem, info: dict):
        """Certified spread bounds from a sketch-selection certificate
        (:func:`~repro.core.coverage.select_seeds_sketch` ``info_out``):
        lower from the deterministic Δocc sum, upper from the z-sigma
        linear-counting error — widened to the whole pool on a saturated
        union row, never a silently-finite estimate."""
        n_rr = max(int(info.get("n_rr", 0)), 1)
        return (r.scale * float(info["lo_rows"]) / n_rr,
                r.scale * float(info["hi_rows"]) / n_rr)

    def _degraded_result(self, r: ResolvedProblem) -> IMResult:
        """Deadline-clipped answer from the pool sampled so far (DESIGN.md
        §8): greedy over the packed coverage sketch (certified Δ-occupancy
        lower bounds per pick) with an exact-Occur union upper bound, never
        a silently wrong exact answer.  Only counting objectives qualify —
        weighted/budgeted/MRIM objectives have no certified sketch
        estimate, so they raise :class:`DeadlineExceeded` instead."""
        p = r.problem
        st = self.store
        if (p.budget is not None or r.node_weights is not None
                or self._row_weight_mode or p.t_rounds is not None):
            raise DeadlineExceeded(
                f"deadline expired mid-solve and the {p.variant!r} "
                "objective has no certified sketch estimate")
        n_rr = st.n_rr
        if n_rr == 0:
            raise DeadlineExceeded("deadline expired before any sampling "
                                   "round completed")
        if getattr(st, "pool_free", False):
            # approximate mode clipped mid-solve: its selection path is
            # already the certified sketch greedy — run it over whatever
            # was folded so far and mark the answer degraded
            info = {}
            res = cov.select_seeds_sketch(st, r.k_steps,
                                          cand=r.cand_mask_items,
                                          info_out=info)
            seeds, gains, frac = jax.device_get(
                (res.seeds, res.gains, res.frac))
            seeds, gains = np.asarray(seeds), np.asarray(gains)
            live = seeds < r.n_items
            seeds, gains = seeds[live], gains[live]
            frac = float(frac)
            self._materialize_stats()
            self._stats.frac_covered = frac
            self._stats.variant = p.variant
            return IMResult(
                seeds=seeds.astype(np.int64), spread=r.scale * frac,
                gains=gains.astype(np.int64), frac=frac,
                stats=self.stats, problem=p, n_nodes=self.n,
                degraded=True, spread_bounds=self._approx_bounds(r, info))
        fns = cov._mesh_select_fns(st.mesh)
        # exact per-item row counts: the union upper bound + the
        # sketch-free fallback ranking (one mesh reduction, explicit fetch)
        occ_exact = np.asarray(jax.device_get(fns.occur(
            st._flat, st._valid, n=st.n_nodes)), np.int64)[:r.n_items]
        mask = (np.ones(r.n_items, bool) if r.cand_mask_items is None
                else r.cand_mask_items.copy())
        seeds, lb_gains = [], []
        if st.sketch_k is not None:
            # sketch greedy: k sweeps, each pick scored by its certified
            # Δocc (distinct sketch buckets newly covered ≤ distinct rows
            # newly covered), the pick folded into the union sketch
            stripe = st.sketch_rows // st.n_shards
            sk = st.sketch_words_mesh()
            cov_sk = jax.device_put(
                np.zeros((st.n_shards, st.sketch_k // 32), np.uint32),
                st._sh_buf)
            for _ in range(r.k_steps):
                docc = np.asarray(jax.device_get(
                    fns.sweep(sk, cov_sk, stripe=stripe)))[:r.n_items]
                docc = np.where(mask, docc, -1)
                u = int(docc.argmax())
                if docc[u] < 0:
                    break
                seeds.append(u)
                lb_gains.append(int(docc[u]))
                mask[u] = False
                cov_sk = fns.union(
                    cov_sk, sk, jax.device_put(np.int32(u), st._sh_rep))
            covered_lb = float(sum(lb_gains))
        else:
            # no sketch on this pool: rank by exact per-item counts
            # (overlap-blind).  Any single seed covers occ_exact[seed]
            # rows, so the best pick alone is a certified lower bound.
            order = np.argsort(np.where(mask, occ_exact, -1))[::-1]
            seeds = [int(u) for u in order[:r.k_steps] if mask[u]]
            lb_gains = [int(occ_exact[u]) for u in seeds]
            covered_lb = float(max(lb_gains, default=0))
        covered_ub = float(min(n_rr, sum(int(occ_exact[u]) for u in seeds)))
        # point estimate: linear counting on the union occupancy, clamped
        # into the certified bracket
        if st.sketch_k is not None and seeds:
            est = float(sketch_mod.linear_count(
                np.asarray([int(sum(lb_gains))]), st.sketch_k)[0])
        else:
            est = covered_lb
        est = min(max(est, covered_lb), covered_ub)
        frac = est / n_rr
        self._materialize_stats()
        self._stats.frac_covered = frac
        self._stats.variant = p.variant
        lo, hi = (r.scale * covered_lb / n_rr, r.scale * covered_ub / n_rr)
        return IMResult(
            seeds=np.asarray(seeds, np.int64), spread=r.scale * frac,
            gains=np.asarray(lb_gains, np.int64), frac=frac,
            stats=self.stats, problem=p, n_nodes=self.n,
            degraded=True, spread_bounds=(lo, hi))

    # -- full IMM ----------------------------------------------------------
    def solve(self, problem: Optional[IMProblem] = None,
              *_args, **_kw) -> IMResult:
        """Solve an :class:`~repro.core.problem.IMProblem` -> ``IMResult``.

        The pre-problem positional form ``solve(k, eps)`` was removed after
        its one-release deprecation window (DESIGN.md §6): construct an
        ``IMProblem`` and read ``res.seeds / res.spread / res.stats``.
        """
        if not isinstance(problem, IMProblem) or _args or _kw:
            raise TypeError(
                "IMMSolver.solve() takes exactly one IMProblem; the "
                "deprecated solve(k, eps) form was removed — write "
                "solve(IMProblem(k=..., eps=..., max_theta=...)) and set "
                "ell/max_theta on the problem (DESIGN.md §6)")
        return self.solve_problem(problem)

    def solve_problem(self, problem: IMProblem, *,
                      deadline_s: Optional[float] = None) -> IMResult:
        """``deadline_s`` (seconds of remaining budget, serving-side) turns
        on the in-solve deadline check between LB iterations: once it
        expires the solve returns a ``degraded=True`` sketch-bound answer
        over the pool sampled so far instead of blowing the deadline —
        or raises :class:`~repro.ft.failures.DeadlineExceeded` when the
        objective has no certified sketch estimate (DESIGN.md §8)."""
        r = self._prepare(problem)
        spec = self._selection_spec(r)
        scale = r.scale
        p = problem
        k_theta = p.k if p.k is not None else r.k_steps
        deadline = (time.monotonic() + deadline_s
                    if deadline_s is not None else None)
        # resume: a restored pool carrying this very solve's digest picks
        # the LB loop back up at stats.lb_completed + 1 — re-running
        # completed iterations over the larger restored pool would shift
        # their est/break points and fork from the uninterrupted stream
        sig = p.signature_digest()
        resume = (self._active_solve == sig)
        self._active_solve = sig

        def _expired() -> bool:
            return deadline is not None and time.monotonic() >= deadline

        self._sketch_info = None

        def _select():
            if getattr(self.store, "pool_free", False):
                # approximate mode: no pool to verify against — selection
                # runs purely on sketch estimates and leaves its error
                # certificate in _sketch_info for the final spread_bounds
                info = {}
                self._sketch_info = info
                fn = (lambda: cov.select_seeds_sketch(
                    self.store, r.k_steps, cand=r.cand_mask_items,
                    info_out=info))
            else:
                fn = (lambda: self.store.select(r.k_steps,
                                                method=self._sel_method,
                                                spec=spec,
                                                eval_batch=self.eval_batch))
            if self.fault_policy is not None:
                # ctx identifies the request so a match-gated injector can
                # poison one problem in a batch (serving isolation tests)
                return self.fault_policy.run(fn, "select",
                                             {"problem": p, "k": r.k_steps})
            return fn()

        with jax.transfer_guard(self._guard):
            if p.theta is not None:
                # fixed-θ mode (benchmarks, MRIM's Table-3 experiment):
                # sample to θ, one selection, no LB loop.  Re-entry after a
                # restore needs no resume bookkeeping: sample_until tops up
                # from the watermark and selection is pool-deterministic.
                self._stats.theta = p.theta
                self._stats.lb = 1.0
                self.sample_until(p.theta)
                if _expired():
                    return self._degraded_result(r)
                res = _select()
            elif resume and self._stats.theta:
                # the LB loop had already concluded when the checkpoint was
                # written; only the final θ top-up remains
                self.sample_until(self._stats.theta)
                res = _select()
            else:
                lam_p, lam_star, eps_p, _ = imm_theta_params(
                    self.n, k_theta, p.eps, p.ell)
                lb = self._stats.lb if resume else 1.0
                start_i = (self._stats.lb_completed + 1) if resume else 1
                res = None
                for i in range(start_i,
                               max(int(math.log2(self.n)), 2)):  # Alg. 2
                    if _expired():
                        return self._degraded_result(r)
                    x = scale / (2.0 ** i)
                    theta_i = int(math.ceil(lam_p / x))
                    if p.max_theta:
                        theta_i = min(theta_i, p.max_theta)
                    self.sample_until(theta_i)
                    threshold = (1.0 + eps_p) * x
                    if self._early_exit_skip(r, threshold):
                        self._stats.early_exit_skips += 1
                        self._stats.history.append(
                            ("lb_skip", i, theta_i))
                        self._stats.lb_completed = i
                        continue
                    res = _select()
                    # explicit scalar fetch: Alg. 2 L7 break is host control
                    est = scale * float(jax.device_get(res.frac))
                    self._stats.lb_iters = i
                    self._stats.history.append(("lb_iter", i, theta_i, est))
                    if est >= threshold:                         # Alg. 2 L7
                        lb = est / (1.0 + eps_p)                 # Alg. 2 L8
                        break
                    self._stats.lb_completed = i
                    self._stats.lb = lb
                theta = int(math.ceil(lam_star / lb))
                if p.max_theta:
                    theta = min(theta, p.max_theta)
                self._stats.theta = theta
                self._stats.lb = lb
                if _expired():
                    return self._degraded_result(r)
                self.sample_until(theta)
                res = _select()
        self._active_solve = None
        # final result materialization — the loop's only bulk transfer
        spent_dev = getattr(res, "spent", None)
        fetched = jax.device_get(
            (res.seeds, res.gains, res.frac)
            + ((spent_dev,) if spent_dev is not None else ()))
        seeds, gains, frac = fetched[0], fetched[1], float(fetched[2])
        spent = float(fetched[3]) if spent_dev is not None else 0.0
        seeds = np.asarray(seeds)
        gains = np.asarray(gains)
        live = seeds < r.n_items          # budgeted scans pad with sentinels
        seeds, gains = seeds[live], gains[live]
        self._stats.frac_covered = frac
        self._stats.variant = p.variant
        self._stats.budget_spent = spent
        spread = scale * frac                                    # Eq. (3)
        bounds = (self._approx_bounds(r, self._sketch_info)
                  if self._sketch_info else None)
        return IMResult(seeds=seeds, spread=spread, gains=gains, frac=frac,
                        stats=self.stats, problem=p, n_nodes=self.n,
                        cost=spent, spread_bounds=bounds)

    def solve_stacked(self, problems: "list[IMProblem]") -> "list[IMResult]":
        """Fixed-θ micro-batch solve: one padded
        :func:`~repro.core.coverage.select_seeds_stacked` scan over the
        shared pool instead of one selection per request — the serving
        front's batched-selection path (DESIGN.md §11).

        Every problem must pin the same ``theta`` and share this solver's
        pool signature (the front batches by registry key, which guarantees
        both — ``_prepare`` would rebuild the pool otherwise), and each
        returned :class:`IMResult` is bit-identical to ``solve_problem`` on
        the same solver at any mesh width.  ``mode="approximate"`` and the
        row-weighted fallback estimator are not stackable; callers route
        those per request.
        """
        if not problems:
            return []
        theta = problems[0].theta
        for p in problems:
            if p.theta is None or p.theta != theta:
                raise ValueError(
                    "solve_stacked needs one common fixed theta= on every "
                    "problem (LB-loop solves cannot share a scan)")
            if p.mode == "approximate":
                raise ValueError("solve_stacked needs the exact pool; "
                                 "approximate-mode problems go solo")
        rs, sig0 = [], None
        for p in problems:
            rs.append(self._prepare(p))
            if sig0 is None:
                sig0 = self._sig
            elif self._sig != sig0:
                raise ValueError("all stacked problems must share one pool "
                                 "signature (solver_key batches do)")
        if self._row_weight_mode:
            raise ValueError("solve_stacked does not support the "
                             "row-weighted fallback estimator")
        specs = [self._selection_spec(r) for r in rs]
        n_group = n_groups = None
        reqs = []
        for r, spec in zip(rs, specs):
            if spec is None:
                reqs.append(cov.StackedRequest(k_steps=r.k_steps))
                continue
            reqs.append(cov.StackedRequest(
                k_steps=spec.k_steps, plain=False, cand=spec.cand,
                costs=spec.costs, budget=spec.budget,
                quota=spec.group_quota))
            if n_group is None:
                n_group, n_groups = spec.n_group, spec.n_groups
            elif (n_group, n_groups) != (spec.n_group, spec.n_groups):
                # unreachable when batched by registry key: the geometry
                # derives from t_rounds, which is part of the pool signature
                raise ValueError("mixed group geometry in a stacked batch")
        with jax.transfer_guard(self._guard):
            self._stats.theta = theta
            self._stats.lb = 1.0
            self.sample_until(theta)
            sel = (lambda: cov.select_seeds_stacked(
                self.store, reqs,
                n_group=n_group if n_group is not None else self.n,
                n_groups=n_groups if n_groups is not None else 1))
            if self.fault_policy is not None:
                # the scan is one fused call, but the "select" fault
                # boundary still fires once per request with the solo
                # ctx — a match-gated injector can poison one problem,
                # and the serving front quarantines the batch and
                # re-runs each request alone (front._run_group)
                for p, r in zip(problems, rs):
                    self.fault_policy.run(
                        lambda: None, "select",
                        {"problem": p, "k": r.k_steps, "stacked": True})
                out = self.fault_policy.run(
                    sel, "select", {"stacked_batch": len(problems)})
            else:
                out = sel()
        seeds_all, gains_all, frac_all, spent_all = jax.device_get(
            (out.seeds, out.gains, out.frac, out.spent))
        results = []
        for i, (p, r) in enumerate(zip(problems, rs)):
            seeds = np.asarray(seeds_all[i, :r.k_steps])
            gains = np.asarray(gains_all[i, :r.k_steps])
            live = seeds < r.n_items      # sentinel trim, as in solve_problem
            seeds, gains = seeds[live], gains[live]
            frac = float(frac_all[i])
            spent = float(spent_all[i])
            self._stats.frac_covered = frac
            self._stats.variant = p.variant
            self._stats.budget_spent = spent
            results.append(IMResult(
                seeds=seeds, spread=r.scale * frac, gains=gains, frac=frac,
                stats=self.stats, problem=p, n_nodes=self.n, cost=spent))
        return results

    # -- streaming graphs (DESIGN.md §9) -----------------------------------
    def resolve_incremental(self, problem: IMProblem, deltas, *,
                            min_surviving_fraction: float = 0.0,
                            deadline_s: Optional[float] = None) -> IMResult:
        """Apply edge ``deltas`` (``repro.core.stream`` spec) to the
        solver's graph and re-solve ``problem``, reusing every RR set the
        deltas provably leave untouched.

        A forward edge u→v lives in reverse-adjacency row v, and an RR-BFS
        only examines the rows of nodes it visits — so a pre-delta RR set
        containing no destination of any changed edge ran an identical-law
        trajectory on both graphs and survives as an exact post-delta
        sample *conditioned on avoiding the changed rows*
        (:func:`repro.core.stream.affected_nodes`; DESIGN.md §9 states the
        guarantee and the residual conditioning term, which the KS/5σ
        conformance suite polices).  Touched rows are evicted
        (``evict_rows_containing``), the engine rebuilds on the mutated
        reverse graph, and θ tops back up through the normal
        FaultPolicy-wrapped ``sample_until`` loop — checkpoints, resume and
        the transfer guard all keep working.

        The pool is reused only when its signature matches ``problem``
        (same pool digest / engine / sketch) — otherwise, and when fewer
        than ``min_surviving_fraction`` of the rows survive, the solve
        falls back to a cold pool on the post-delta graph.  MRIM problems
        (``t_rounds``) are rejected: their tagged item space has no
        per-node invalidation frontier.  Reuse bookkeeping lands in
        ``self.last_incremental`` and the stats history (``"delta"``
        entry).
        """
        from repro.core import stream as stream_mod
        if not isinstance(self._engine_arg, str):
            raise ValueError(
                "resolve_incremental needs a string engine= (the solver "
                "rebuilds its engine on the mutated graph); an engine "
                "instance owns its own graph and cannot be re-pointed")
        if problem.t_rounds is not None:
            raise ValueError(
                "resolve_incremental does not support MRIM (t_rounds=): "
                "the round-tagged item space has no per-node invalidation "
                "frontier")
        if problem.mode == "approximate":
            raise ValueError(
                "resolve_incremental needs the exact pool (mode="
                "'approximate' keeps no RR rows to invalidate); re-solve "
                "from a cold sketch instead")
        d = stream_mod.as_deltas(deltas)
        new_g = stream_mod.apply_edge_deltas(self.g, d)
        aff = stream_mod.affected_nodes(d)
        # reuse is sound only for a same-signature pool: the expected sig
        # mirrors _prepare's keying exactly
        model = problem.model or self._default_model()
        sketch_k = self._sketch_k_arg
        if sketch_k is None and (self._sel_method == "celf"
                                 or problem.early_exit):
            sketch_k = cov.ShardedDeviceRRStore.DEFAULT_SKETCH_K
        name = resolve_engine_name(self._engine_arg, model)
        want_sig = ("name", name, problem.pool_digest(model=model), sketch_k)
        store = self._store_obj if self._sig == want_sig else None
        info = {"affected_nodes": int(aff.shape[0]),
                "n_rr_before": store.n_rr if store is not None else 0,
                "rows_dropped": 0, "rows_kept": 0,
                "surviving_fraction": 0.0, "reused": False}
        if store is not None:
            ev = store.evict_rows_containing(aff)
            info["rows_dropped"] = int(ev["rows_dropped"])
            info["rows_kept"] = int(ev["rows_kept"])
            if info["n_rr_before"]:
                info["surviving_fraction"] = (info["rows_kept"]
                                              / info["n_rr_before"])
            if info["surviving_fraction"] < min_surviving_fraction:
                store = None                     # cold restart: too few left
        # swap in the post-delta graph and force the engine rebuild; the
        # RNG cursor carries over (sampling continues the stream)
        self.g = new_g
        self.n = new_g.n_nodes
        self.g_rev = reverse(new_g)
        self._sig = None
        self._engine_obj = None
        self._active_solve = None
        self._last_ckpt_round = 0
        if store is not None:
            # adoption path: fresh stats/accumulators, surviving pool kept
            self._prepare(problem, _store=store)
            info["reused"] = True
            self._stats.history.append(
                ("delta", info["rows_dropped"], info["rows_kept"]))
        else:
            self._store_obj = None
        self.last_incremental = info
        return self.solve_problem(problem, deadline_s=deadline_s)


_SOLVER_KEYS = frozenset(("engine", "batch", "qcap", "ec", "model", "seed",
                          "selection", "sketch_k", "eval_batch", "mesh",
                          "fault_policy", "checkpoint_dir",
                          "checkpoint_every", "checkpoint_keep"))
_PROBLEM_KEYS = frozenset(("model", "ell", "max_theta", "node_weights",
                           "costs", "budget", "candidates", "t_rounds",
                           "theta", "early_exit", "mode"))


def imm(g: CSRGraph, k: Optional[int] = None, eps: Optional[float] = None,
        **kw):
    """One-shot convenience wrapper; returns (seeds, spread_estimate, stats).

    Keyword arguments split between the solver (engine/batch/selection/...)
    and the problem (node_weights/costs/budget/candidates/t_rounds/...);
    anything else raises ``TypeError`` — the historical whitelist filter
    silently swallowed typos like ``sketchk=64``.
    """
    unknown = set(kw) - _SOLVER_KEYS - _PROBLEM_KEYS
    if unknown:
        raise TypeError("imm() got unexpected keyword argument(s): "
                        + ", ".join(sorted(unknown)))
    solver_kw = {k_: v for k_, v in kw.items() if k_ in _SOLVER_KEYS}
    pkw = {k_: v for k_, v in kw.items()
           if k_ in _PROBLEM_KEYS and k_ != "model" and v is not None}
    if kw.get("model") is not None:
        pkw["model"] = kw["model"]
    if k is not None:
        pkw["k"] = k
    if eps is not None:
        pkw["eps"] = eps
    res = IMMSolver(g, **solver_kw).solve_problem(IMProblem(**pkw))
    return res.seeds, res.spread, res.stats


def imm_result(g: CSRGraph, problem: IMProblem, **solver_kw) -> IMResult:
    """Typed one-shot: ``IMMSolver(g, **solver_kw).solve(problem)``."""
    unknown = set(solver_kw) - _SOLVER_KEYS
    if unknown:
        raise TypeError("imm_result() got unexpected keyword argument(s): "
                        + ", ".join(sorted(unknown)))
    return IMMSolver(g, **solver_kw).solve_problem(problem)

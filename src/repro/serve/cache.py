"""Result cache for the IM serving layer.

Keys are the *content* of a request — the graph name **and its content
digest** (:func:`repro.graph.csr.graph_digest` — a re-registered or
delta-mutated graph can never return a pre-mutation cached result), plus
the problem's :meth:`~repro.core.problem.IMProblem.signature_digest`
(sha256 over every field, arrays by dtype+shape+bytes), plus the
solver-config discriminator the registry derives — so two requests hit
the same entry iff a solve for one would be bit-identical to a solve for
the other on the same warm solver.  Values are host-side
:class:`~repro.core.problem.IMResult` objects (numpy seeds/gains +
python scalars); treat them as immutable.

Plain LRU over an ``OrderedDict`` with hit/miss/eviction counters — the
numbers surface in :class:`~repro.serve.front.ServeStats` and the
``BENCH_serving.json`` artifact.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Hashable, Optional

from repro.core.problem import IMResult


@dataclass(frozen=True)
class CacheStats:
    hits: int
    misses: int
    evictions: int
    entries: int
    max_entries: int

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class ResultCache:
    """LRU map ``request key -> IMResult`` with counters."""

    def __init__(self, max_entries: int = 1024):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self._d: "OrderedDict[Hashable, IMResult]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key: Hashable) -> Optional[IMResult]:
        hit = self._d.get(key)
        if hit is None:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return hit

    def put(self, key: Hashable, result: IMResult) -> None:
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = result
        while len(self._d) > self.max_entries:
            self._d.popitem(last=False)
            self.evictions += 1

    def snapshot(self) -> CacheStats:
        return CacheStats(hits=self.hits, misses=self.misses,
                          evictions=self.evictions, entries=len(self._d),
                          max_entries=self.max_entries)

"""Sketch-based coverage estimation (Cohen et al., sketch-based IM).

The fused Alg. 7 greedy recomputes marginal coverage over the *full* RR pool
every round — O(elements) per seed.  A bottom-k-style sketch answers the
same "how many uncovered RR rows does candidate v hit?" question from a
fixed-size summary:

For every node v we keep a **hashed one-permutation occupancy sketch**: a
k-bucket bitmap where bucket ``h(row_id) mod k`` is set iff some RR row
containing v hashed there.  Unions are bitwise OR, cardinality proxies are
popcounts — exactly the packed-bitset plumbing of ``kernels/bitset.py``, so
the per-candidate union estimate over all n nodes is one Pallas popcount
sweep (``kernels/sketch.py``).

The occupancy is maintained **directly as packed uint32 words** — an
(R, k/32) uint32 matrix, never an (R, k) bool one.  Scatter-OR into packed
words is not a plain scatter (two bits landing in one word must combine,
and a bit already present must not carry), so the fold
(:func:`scatter_or_bits`) lexsorts the batch's (row, bucket) pairs, keeps
first occurrences, masks bits already set in the live words, and commits
the survivors with one scatter-*add* — which at that point is exactly
scatter-OR.  This is the ~8× sketch-memory cut over the historical bool
occupancy (deleted); the equivalent TPU-bound Pallas kernel lives in
``kernels/sketch.py`` (:func:`~repro.kernels.sketch.sketch_scatter_or`)
and is property-tested bit-identical.

Properties the CELF selection path (``coverage.select_seeds_celf``) relies
on:

* **Lower bound** — new occupied buckets require new rows, so
  ``Δocc(v | S) = occ(sketch_v | sketch_S) − occ(sketch_S)`` never exceeds
  the exact marginal coverage of v.  CELF therefore uses Δocc only to
  *order* candidates for exact verification; correctness never depends on
  sketch accuracy.
* **Exact-safe regime** — with the default ``"mod"`` bucketing
  (``bucket = row_id % k``) the map is injective while ``n_rr <= k``, so
  Δocc *equals* the exact marginal gain and one verification per seed
  suffices.  Past k rows the sketch degrades gracefully into a uniform
  hash (sequential row ids stride the buckets perfectly).
* **Incremental** — ``ShardedDeviceRRStore.append_batch`` folds each batch
  into the packed words with one jit'd sort+scatter (O(batch elements
  · log), no rebuild); on a multi-device mesh the fold runs replicated
  (every device folds the identical full batch — cheaper than any
  cross-device OR of sketch deltas, see DESIGN.md §5).

Cardinality estimation for consumers that want absolute counts (benchmarks,
tests) is classic linear counting: ``n̂ = k · ln(k / (k − occ))``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitset import _popcount


def resolve_sketch_k(k: int) -> int:
    """Round the bucket count up to a whole number of uint32 words."""
    if k <= 0:
        raise ValueError("sketch_k must be positive")
    return ((k + 31) // 32) * 32


def bucket_of(row_ids, k: int, mode: str = "mod"):
    """Bucket index of each RR row id (jit-traceable).

    ``"mod"`` — identity modulo k: injective (exact) while ids < k, a
    perfect stride afterwards.  ``"mix"`` — Knuth multiplicative hash then
    modulo, for adversarial id patterns.
    """
    rid = row_ids.astype(jnp.uint32)
    if mode == "mix":
        rid = rid * jnp.uint32(2654435761)
    elif mode != "mod":
        raise ValueError(f"unknown sketch hash mode {mode!r}")
    return (rid % jnp.uint32(k)).astype(jnp.int32)


def scatter_or_bits(words, v, b):
    """Scatter-OR bucket bits into packed words: ``words[v] |= 1 << b``.

    ``words`` (R, W) uint32, ``v``/``b`` (E,) int32 flat (row, bucket)
    pairs; entries with ``v >= R`` are dropped (sentinels).  Duplicate
    pairs and bits already present are handled exactly: pairs are lexsorted
    and deduplicated, surviving bits are masked against the current words
    (one gather), and the remainder — now provably absent and pairwise
    distinct — commits via scatter-add, which equals scatter-OR on disjoint
    bits.  O(E log E) work, no bool buffer of any size.
    """
    n_rows = words.shape[0]
    order = jnp.lexsort((b, v))
    vs, bs = v[order], b[order]
    first = jnp.concatenate(
        [jnp.ones((1,), bool), (vs[1:] != vs[:-1]) | (bs[1:] != bs[:-1])])
    wi = bs >> 5
    bit = jnp.uint32(1) << (bs & 31).astype(jnp.uint32)
    cur = words[jnp.clip(vs, 0, n_rows - 1), jnp.clip(wi, 0, words.shape[1] - 1)]
    new = jnp.where(first & (vs < n_rows) & ((cur & bit) == 0),
                    bit, jnp.uint32(0))
    return words.at[vs, wi].add(new, mode="drop")


def fold_batch_packed(words, nodes, lens, row_base, *, k, mode):
    """Fold one padded batch into the packed (R, k/32) occupancy words.

    ``row_base`` is the pool's *global* row count before this batch (device
    scalar), so bucketing matches the canonical batch-order row numbering
    regardless of how the pool itself is sharded.  Rows with length 0 are
    padding and contribute nothing.  Plain traceable function — the store
    jits it directly (single device) or per shard inside ``shard_map``
    (every device folds the identical replicated batch).
    """
    r, w = nodes.shape
    n_rows = words.shape[0]
    lens = jnp.minimum(jnp.maximum(lens.astype(jnp.int32), 0), w)
    mask = jnp.arange(w, dtype=jnp.int32)[None, :] < lens[:, None]
    row_valid = lens > 0
    rid = row_base + jnp.cumsum(row_valid, dtype=jnp.int32) - 1
    b = jnp.broadcast_to(bucket_of(rid, k, mode)[:, None], (r, w)).reshape(-1)
    v = jnp.where(mask, nodes.astype(jnp.int32), n_rows).reshape(-1)
    return scatter_or_bits(words, v, b)


def fold_frontier_rows(words, nodes, lens, row_ids, *, k, mode,
                       interpret=None):
    """Fold a padded frontier batch into the packed words — the pool-free
    hot path (``mode="approximate"``).

    Unlike :func:`fold_batch_packed` (sized for occasional pool-side
    folds), this commits the raw (row, bucket) pairs without dedup: OR is
    idempotent, so duplicates are harmless.  On compiled backends the pairs
    go straight through :func:`~repro.kernels.sketch.sketch_scatter_or` —
    O(E) serial RMW, the moral ``atomicOr`` loop of gIM.  Under interpret
    mode (CPU) that kernel's per-element load/store degrades to a full
    (R, W) copy per pair, so the fold falls back to the vectorized
    sort-based :func:`scatter_or_bits` — property-tested bit-identical to
    the kernel, so the dispatch is invisible in results.  ``interpret``
    resolves through the shared kernel policy; jitted callers must resolve
    it *outside* their trace and pass the concrete bool (it picks the
    algorithm, so a baked-in stale choice would survive jit caching).

    ``row_ids`` are the per-row global RR ids (precomputed by the caller so
    sharded callers can number over the full batch); rows with length 0 are
    padding.
    """
    from repro.kernels import ops as kops
    r, w = nodes.shape
    n_rows = words.shape[0]
    lens = jnp.minimum(jnp.maximum(lens.astype(jnp.int32), 0), w)
    mask = jnp.arange(w, dtype=jnp.int32)[None, :] < lens[:, None]
    b = jnp.broadcast_to(
        bucket_of(row_ids, k, mode)[:, None], (r, w)).reshape(-1)
    v = jnp.where(mask, nodes.astype(jnp.int32), n_rows).reshape(-1)
    if kops.resolve_interpret(interpret):
        return scatter_or_bits(words, v, b)
    return kops.sketch_scatter_or(words, v, b, interpret=False)


def fold_frontier_packed(words, nodes, lens, row_base, *, k, mode,
                         interpret=None):
    """:func:`fold_frontier_rows` with canonical batch-order row numbering
    (``row_base`` = global rows before this batch) — the single-device
    convenience entry; bit-identical to :func:`fold_batch_packed` on the
    same batch."""
    row_valid = lens.astype(jnp.int32) > 0
    rid = row_base + jnp.cumsum(row_valid, dtype=jnp.int32) - 1
    return fold_frontier_rows(words, nodes, lens, rid, k=k, mode=mode,
                              interpret=interpret)


def flat_to_packed_bits(flat, ids, valid, *, n_rows, k, mode):
    """(flat pool → (v, b) pairs) for :func:`scatter_or_bits`."""
    b = bucket_of(ids, k, mode)
    v = jnp.where(valid, flat.astype(jnp.int32), n_rows)
    return v, b


@functools.partial(jax.jit, static_argnames=("n_rows", "k", "mode"))
def sketch_packed_from_flat(flat, ids, valid, *, n_rows, k, mode):
    """Build packed (n_rows, k/32) occupancy words from an existing flat
    pool (stores created without an incremental sketch).

    Also the windowed-eviction rebuild path (DESIGN.md §9.3):
    ``ShardedDeviceRRStore._rewrite`` re-derives the sketch from the
    surviving flat pool with shard-major renumbered row ids.  Bucketing
    reads only row ids — never pool positions — so any injective
    renumbering composes bit-identically with later ``append_batch``
    folds (pinned by the sketch-rebuild conformance test).
    """
    v, b = flat_to_packed_bits(flat, ids, valid, n_rows=n_rows, k=k,
                               mode=mode)
    return scatter_or_bits(jnp.zeros((n_rows, k // 32), jnp.uint32), v, b)


@functools.partial(jax.jit, static_argnames=("n", "k", "mode"))
def sketch_from_flat(flat, ids, valid, *, n, k, mode):
    """Bool (n+1, k) occupancy from a flat pool — the PR-3 reference fold.

    Kept as the *test oracle* for the packed-word fold (the property suite
    asserts ``pack_sketch(sketch_from_flat(...)) == packed fold`` bit for
    bit); no production path materializes this buffer anymore.
    """
    b = bucket_of(ids, k, mode)
    v = jnp.where(valid, flat, n + 1)            # OOB -> dropped
    return jnp.zeros((n + 1, k), bool).at[v, b].set(True, mode="drop")


def pack_sketch(occ, *, words):
    """(R, k) bool occupancy -> (R, k/32) uint32 packed words, via the
    Pallas ``pack_bits`` kernel (same LSB-first bit order as the Covered
    bitset and the Visited structures)."""
    from repro.kernels import ops as kops
    if occ.shape[1] != words * 32:
        raise ValueError("occupancy width must be words * 32")
    return kops.pack_bits(occ)


@jax.jit
def union_row(cov_words, sk_words, u):
    """``cov | sketch[u]`` — fold one selected seed into the union sketch."""
    return cov_words | sk_words[u]


@jax.jit
def _minus_base(union_occ, cov_words):
    return union_occ - _popcount(cov_words).sum(dtype=jnp.int32)


def _union_popcount_rows(rows, cov_words):
    """``popcount(rows[v] | cov)`` per row, SWAR-vectorised — the interpret
    fallback for the union-popcount kernel.  Under interpret mode the Pallas
    per-block loop degrades to full-array copies, so the sweep runs this
    elementwise form instead; integer arithmetic makes it bit-identical to
    the kernel output.
    """
    u = rows | cov_words[None, :]
    return _popcount(u).astype(jnp.int32).sum(axis=1, dtype=jnp.int32)


def union_gains(sk_words, cov_words, *, interpret=None):
    """Estimated marginal occupancy Δocc(v | S) for every node, in one
    kernel sweep: ``popcount(sketch[v] | cov) − popcount(cov)``.

    Returns a device (R,) int32 vector (R = sketch rows; callers slice off
    the sentinel row).  Δocc is a certified lower bound on the exact
    marginal coverage (see module docstring).  ``interpret`` picks the
    algorithm (kernel vs SWAR fallback) like :func:`fold_frontier_rows`;
    jitted callers must resolve it outside their trace.
    """
    from repro.kernels import ops as kops
    if kops.resolve_interpret(interpret):
        return _minus_base(_union_popcount_rows(sk_words, cov_words),
                           cov_words)
    return _minus_base(
        kops.sketch_union_popcount(sk_words, cov_words, interpret=False),
        cov_words)


def union_gains_stripe(sk_words, cov_words, stripe_start, stripe_rows: int,
                       *, interpret=None):
    """Δocc for one contiguous stripe of sketch rows — the shard-local body
    of the mesh-parallel sweep (each device scores its stripe of candidates
    against its sketch replica; a psum of the disjoint stripes yields the
    full replicated vector).  On compiled backends the stripe runs through
    the Pallas union-popcount kernel, so the mesh=1 sweep is exactly the
    historical single-device kernel sweep; under interpret mode it takes
    the bit-identical SWAR fallback (see :func:`_union_popcount_rows`).
    """
    from repro.kernels import ops as kops
    rows = jax.lax.dynamic_slice(
        sk_words, (stripe_start, 0), (stripe_rows, sk_words.shape[1]))
    if kops.resolve_interpret(interpret):
        occ = _union_popcount_rows(rows, cov_words)
    else:
        occ = kops.sketch_union_popcount(rows, cov_words, interpret=False)
    return occ - _popcount(cov_words).sum(dtype=jnp.int32)


def linear_count(occupied, k: int):
    """Linear-counting cardinality estimate from bucket occupancy.

    Exact while the bucketing is injective (``occupied`` distinct rows all
    landed in distinct buckets); otherwise ``k·ln(k/(k−occ))`` corrects for
    collisions (capped at full occupancy).
    """
    occ = np.asarray(occupied, dtype=np.float64)
    occ = np.clip(occ, 0.0, k - 1.0)
    est = k * np.log(k / (k - occ))
    return np.where(np.asarray(occupied) >= k, k * np.log(k), est)


def linear_count_saturated(occupied, k: int):
    """:func:`linear_count` plus a per-entry ``saturated`` flag.

    A fully-occupied row (``occ >= k``) carries no cardinality information
    beyond "at least ~k·ln(k)": the raw formula diverges, so the estimate is
    clamped to that ceiling and flagged.  Consumers that surface estimates
    to users (approximate-mode selection, ``IMResult.spread_bounds``) MUST
    widen their upper bound on saturation instead of reporting the clamp as
    a finite estimate.
    """
    sat = np.asarray(occupied) >= k
    return linear_count(occupied, k), sat


def linear_count_rel_error(est, k: int, *, z: float = 3.0):
    """Certified relative standard-error bound of the linear-counting
    estimate, scaled to ``z`` standard deviations.

    Whang et al.: with load ``t = n/k``, the estimator's relative StdErr is
    ``sqrt(e^t − t − 1) / (t · sqrt(k))`` (asymptotically normal), so a
    z-sigma relative bound is ``z ×`` that.  ``est`` is used as the plug-in
    for n.  Saturated rows (``t`` at the ln(k) ceiling) get whatever the
    formula yields there — callers widen separately via the flag.
    """
    t = np.maximum(np.asarray(est, dtype=np.float64) / k, 1e-9)
    se = np.sqrt(np.maximum(np.expm1(t) - t, 0.0)) / (t * np.sqrt(k))
    return z * se


def auto_sketch_k(eps: float, n: int, *, z: float = 3.0) -> int:
    """Bucket count sized so the certified z-sigma relative error of the
    linear-counting estimate stays within ``eps/2`` at moderate load.

    At the design load ``t = 1`` (n ≈ k rows folded per bucket row) the
    relative StdErr coefficient is ``c = sqrt(e − 2)``; solving
    ``z·c/sqrt(k) <= eps/2`` gives ``k >= (2·z·c/eps)²``.  Clamped to
    ``[64, n]`` (below 64 the normal approximation is junk; above n the
    sketch would outweigh an exact Occur) and rounded to whole uint32
    words.  Higher loads degrade gracefully — the *reported* bound on
    ``spread_bounds`` always uses the realized load via
    :func:`linear_count_rel_error`, never this design point.
    """
    if not (0.0 < eps < 1.0):
        raise ValueError("eps must lie in (0, 1)")
    c = math.sqrt(math.e - 2.0)
    k = math.ceil((2.0 * z * c / eps) ** 2)
    k = max(64, min(k, max(int(n), 64)))
    return resolve_sketch_k(k)

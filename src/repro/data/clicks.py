"""Synthetic Criteo-like clickstream for DeepFM (deterministic per step)."""
from __future__ import annotations

import numpy as np

from repro.models.deepfm import DeepFMConfig


def click_batch(step: int, cfg: DeepFMConfig, batch: int, seed: int = 0):
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    ids = np.zeros((batch, cfg.n_sparse), dtype=np.int64)
    offsets = cfg.field_offsets
    for f, v in enumerate(cfg.field_vocabs):
        # zipf-ish skew within each field
        r = np.minimum(rng.zipf(1.2, size=batch), v) - 1
        ids[:, f] = offsets[f] + r
    dense = rng.normal(size=(batch, cfg.n_dense_feats)).astype(np.float32)
    # labels correlated with a hidden linear model over dense feats
    w = np.random.default_rng(seed).normal(size=cfg.n_dense_feats)
    p = 1.0 / (1.0 + np.exp(-(dense @ w)))
    labels = (rng.random(batch) < p).astype(np.float32)
    return ids.astype(np.int32), dense, labels

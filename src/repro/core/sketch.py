"""Sketch-based coverage estimation (Cohen et al., sketch-based IM).

The fused Alg. 7 greedy recomputes marginal coverage over the *full* RR pool
every round — O(elements) per seed.  A bottom-k-style sketch answers the
same "how many uncovered RR rows does candidate v hit?" question from a
fixed-size summary:

For every node v we keep a **hashed one-permutation occupancy sketch**: a
k-bucket bitmap where bucket ``h(row_id) mod k`` is set iff some RR row
containing v hashed there.  Unions are bitwise OR, cardinality proxies are
popcounts — exactly the packed-bitset plumbing of ``kernels/bitset.py``, so
the per-candidate union estimate over all n nodes is one Pallas popcount
sweep (``kernels/sketch.py``).

Properties the CELF selection path (``coverage.select_seeds_celf``) relies
on:

* **Lower bound** — new occupied buckets require new rows, so
  ``Δocc(v | S) = occ(sketch_v | sketch_S) − occ(sketch_S)`` never exceeds
  the exact marginal coverage of v.  CELF therefore uses Δocc only to
  *order* candidates for exact verification; correctness never depends on
  sketch accuracy.
* **Exact-safe regime** — with the default ``"mod"`` bucketing
  (``bucket = row_id % k``) the map is injective while ``n_rr <= k``, so
  Δocc *equals* the exact marginal gain and one verification per seed
  suffices.  Past k rows the sketch degrades gracefully into a uniform
  hash (sequential row ids stride the buckets perfectly).
* **Incremental** — ``DeviceRRStore.append_batch`` folds each batch into
  the sketch with one jit'd scatter (O(batch elements), no rebuild); the
  packed word matrix is cached per live extent like the bitset matrix.

Cardinality estimation for consumers that want absolute counts (benchmarks,
tests) is classic linear counting: ``n̂ = k · ln(k / (k − occ))``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitset import _popcount


def resolve_sketch_k(k: int) -> int:
    """Round the bucket count up to a whole number of uint32 words."""
    if k <= 0:
        raise ValueError("sketch_k must be positive")
    return ((k + 31) // 32) * 32


def bucket_of(row_ids, k: int, mode: str = "mod"):
    """Bucket index of each RR row id (jit-traceable).

    ``"mod"`` — identity modulo k: injective (exact) while ids < k, a
    perfect stride afterwards.  ``"mix"`` — Knuth multiplicative hash then
    modulo, for adversarial id patterns.
    """
    rid = row_ids.astype(jnp.uint32)
    if mode == "mix":
        rid = rid * jnp.uint32(2654435761)
    elif mode != "mod":
        raise ValueError(f"unknown sketch hash mode {mode!r}")
    return (rid % jnp.uint32(k)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "mode"),
                   donate_argnums=(0,))
def sketch_append(occ, nodes, lens, row_base, *, k, mode):
    """Fold one padded batch into the (n+1, k) bool occupancy sketch.

    ``row_base`` is the pool's row count *before* this batch (device
    scalar), so global row ids match the store's compaction exactly.
    Rows with length 0 are padding and contribute nothing.  Duplicate
    scatter targets all write ``True`` — deterministic, so a plain
    ``.at[].set`` is safe (no scatter-or needed).
    """
    r, w = nodes.shape
    n_rows = occ.shape[0]                        # n + 1 (row n = sentinel bin)
    lens = jnp.minimum(jnp.maximum(lens.astype(jnp.int32), 0), w)
    mask = jnp.arange(w, dtype=jnp.int32)[None, :] < lens[:, None]
    row_valid = lens > 0
    rid = row_base + jnp.cumsum(row_valid, dtype=jnp.int32) - 1
    b = bucket_of(rid, k, mode)                  # (r,)
    v = jnp.where(mask, nodes.astype(jnp.int32), n_rows)   # OOB -> dropped
    return occ.at[v, jnp.broadcast_to(b[:, None], (r, w))].set(
        True, mode="drop")


@functools.partial(jax.jit, static_argnames=("n", "k", "mode"))
def sketch_from_flat(flat, ids, valid, *, n, k, mode):
    """Build the (n+1, k) occupancy sketch from an existing flat pool (for
    stores created without an incremental sketch)."""
    b = bucket_of(ids, k, mode)
    v = jnp.where(valid, flat, n + 1)            # OOB -> dropped
    return jnp.zeros((n + 1, k), bool).at[v, b].set(True, mode="drop")


def pack_sketch(occ, *, words):
    """(R, k) bool occupancy -> (R, k/32) uint32 packed words, via the
    Pallas ``pack_bits`` kernel (same LSB-first bit order as the Covered
    bitset and the Visited structures)."""
    from repro.kernels import ops as kops
    if occ.shape[1] != words * 32:
        raise ValueError("occupancy width must be words * 32")
    return kops.pack_bits(occ)


@jax.jit
def union_row(cov_words, sk_words, u):
    """``cov | sketch[u]`` — fold one selected seed into the union sketch."""
    return cov_words | sk_words[u]


@jax.jit
def _minus_base(union_occ, cov_words):
    return union_occ - _popcount(cov_words).sum(dtype=jnp.int32)


def union_gains(sk_words, cov_words):
    """Estimated marginal occupancy Δocc(v | S) for every node, in one
    kernel sweep: ``popcount(sketch[v] | cov) − popcount(cov)``.

    Returns a device (R,) int32 vector (R = sketch rows; callers slice off
    the sentinel row).  Δocc is a certified lower bound on the exact
    marginal coverage (see module docstring).
    """
    from repro.kernels import ops as kops
    return _minus_base(kops.sketch_union_popcount(sk_words, cov_words),
                       cov_words)


def linear_count(occupied, k: int):
    """Linear-counting cardinality estimate from bucket occupancy.

    Exact while the bucketing is injective (``occupied`` distinct rows all
    landed in distinct buckets); otherwise ``k·ln(k/(k−occ))`` corrects for
    collisions (capped at full occupancy).
    """
    occ = np.asarray(occupied, dtype=np.float64)
    occ = np.clip(occ, 0.0, k - 1.0)
    est = k * np.log(k / (k - occ))
    return np.where(np.asarray(occupied) >= k, k * np.log(k), est)

"""Paper Table 3 (§4.8): multi-round IM (CR-NAIMM) — parallel vs. serial."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ba_graph, write_csv, report
from repro.core import mrim, oracle
from repro.graph import csr as csr_mod

N, R, K, T, N_RR = 4000, 4, 10, 5, 1024


def serial_mrim(g, k, t_rounds, n_rr, seed=0):
    """Numpy CR-NAIMM reference: T tagged BFS per sample."""
    g_rev = csr_mod.reverse(g)
    offs = np.asarray(g_rev.offsets); idx = np.asarray(g_rev.indices)
    w = np.asarray(g_rev.weights)
    rng = np.random.default_rng(seed)
    n = g.n_nodes
    rr = []
    for _ in range(n_rr):
        root = int(rng.integers(n))
        items = []
        for t in range(t_rounds):
            items += [t * n + v
                      for v in oracle.rr_set_ic(offs, idx, w, root, rng)]
        rr.append(items)
    # greedy with per-round budgets
    occur = np.zeros(n * t_rounds, dtype=np.int64)
    node_to_rr = {}
    for i, row in enumerate(rr):
        for v in row:
            occur[v] += 1
            node_to_rr.setdefault(v, []).append(i)
    covered = np.zeros(n_rr, bool)
    budget = {t: k for t in range(t_rounds)}
    picks = []
    for _ in range(k * t_rounds):
        masked = occur.copy()
        for t in range(t_rounds):
            if budget[t] == 0:
                masked[t * n:(t + 1) * n] = -1
        u = int(np.argmax(masked))
        picks.append(u)
        budget[u // n] -= 1
        for i in node_to_rr.get(u, []):
            if not covered[i]:
                covered[i] = True
                for v in rr[i]:
                    occur[v] -= 1
    return picks


def main():
    g = ba_graph(N, R)
    t0 = time.perf_counter()
    serial_mrim(g, K, T, N_RR)
    t_cpu = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = mrim.solve_mrim(g, k=K, t_rounds=T, n_rr=N_RR, batch=128, seed=0)
    t_jax = time.perf_counter() - t0
    rows = [["ba-4000", round(t_jax, 3), round(t_cpu, 3),
             round(t_cpu / t_jax, 2), round(res.spread_estimate, 1)]]
    write_csv("table3_mrim", ["dataset", "t_gim_s", "t_cpu_s", "speedup",
                              "spread_est"], rows)
    report("table3/mrim", t_jax * 1e6, f"speedup={t_cpu / t_jax:.2f}x")


if __name__ == "__main__":
    main()

"""Multi-worker scale-out: one ``IMService`` + event loop per device
group, routed by a consistent-hash ring over registry keys.

**Why a ring over registry keys.**  The expensive serving state is the
warm pool, and a pool's identity is the registry key — ``(graph_digest,
pool_digest, θ, mode)``.  Hashing that route string onto a vnode ring
means every request for one pool lands on exactly one worker (so a pool
is sampled and held once cluster-wide, never duplicated), and worker
join/leave moves only the minimal key range: with V vnodes per worker and
W workers, a join relocates ~1/(W+1) of the keys and a leave exactly the
departed worker's share — everything else keeps its owner bit for bit
(``tests/test_serve_net.py`` asserts both properties).

**Handoff.**  When the ring rebalances, the moved keys' pools travel as
:class:`~repro.core.imm.PoolLease` exports: the old owner's registry pops
the idle entry (:meth:`WarmSolverRegistry.export_entry`), the new owner
adopts the lease (:meth:`~WarmSolverRegistry.adopt_entry`) — RNG cursor
and stats travel with the pool, so the adopted entry keeps answering
bit-identically.  If adoption is impossible (workers pinned to different
device meshes), the lease is dropped and the pool resamples cold on the
new owner; θ-pinned answers are pool-deterministic, so only warm-up cost
is lost, never answer bits.

**Threading.**  Each worker owns a thread running its own event loop and
``IMService`` (whose executor serializes device work per worker).
``IMCluster.submit`` is awaited from any loop and bridges with
``run_coroutine_threadsafe``; ``add_worker``/``remove_worker`` are
blocking control-plane calls — run them from outside the serving loops.
"""
from __future__ import annotations

import asyncio
import bisect
import hashlib
import threading
from typing import Dict, List, Optional

from repro.serve.front import (IMService, ServeConfig, ServeResponse,
                               UnknownGraphError, build_service)
from repro.serve.net import service_statsz


class HashRing:
    """Consistent-hash ring: sha256-placed vnodes, bisect owner lookup."""

    def __init__(self, vnodes: int = 64):
        if vnodes < 1:
            raise ValueError("vnodes must be >= 1")
        self.vnodes = vnodes
        self._workers: "set" = set()
        self._hashes: List[int] = []
        self._owners: List[object] = []

    @staticmethod
    def _hash(s: str) -> int:
        return int.from_bytes(
            hashlib.sha256(s.encode()).digest()[:8], "big")

    def add(self, worker) -> None:
        if worker in self._workers:
            raise ValueError(f"worker {worker!r} already on the ring")
        self._workers.add(worker)
        for v in range(self.vnodes):
            h = self._hash(f"{worker}#{v}")
            i = bisect.bisect_left(self._hashes, h)
            self._hashes.insert(i, h)
            self._owners.insert(i, worker)

    def remove(self, worker) -> None:
        self._workers.remove(worker)
        keep = [(h, w) for h, w in zip(self._hashes, self._owners)
                if w != worker]
        self._hashes = [h for h, _ in keep]
        self._owners = [w for _, w in keep]

    def owner(self, key: str):
        if not self._hashes:
            raise RuntimeError("empty ring")
        i = bisect.bisect_right(self._hashes, self._hash(key))
        return self._owners[i % len(self._owners)]

    @property
    def workers(self):
        return frozenset(self._workers)


class _Worker:
    """A worker thread: its own event loop + started IMService."""

    def __init__(self, wid: int, graphs: dict, config: ServeConfig):
        self.wid = wid
        self.loop = asyncio.new_event_loop()
        self.thread = threading.Thread(
            target=self._run, name=f"im-worker-{wid}", daemon=True)
        self.service: IMService = build_service(graphs, config)

    def _run(self) -> None:
        asyncio.set_event_loop(self.loop)
        self.loop.run_forever()

    def start(self) -> None:
        self.thread.start()
        self.call(self.service.start()).result()

    def call(self, coro):
        """Schedule a coroutine on this worker's loop; returns a
        concurrent future (``.result()`` from sync code, wrap with
        ``asyncio.wrap_future`` to await from another loop)."""
        return asyncio.run_coroutine_threadsafe(coro, self.loop)

    def stop(self) -> None:
        self.call(self.service.stop()).result()
        self.loop.call_soon_threadsafe(self.loop.stop)
        self.thread.join()
        self.loop.close()


async def _export_moving(service: IMService, owned_by, wid, route_of):
    """Runs ON the worker loop: export every idle entry whose ring owner
    is no longer this worker.  Returns [(graph, route, problem, lease)]."""
    moved = []
    reg = service.registry
    for key in list(reg.entries.keys()):
        entry = reg.entries.get(key)
        if entry is None or entry.in_use:
            continue
        route = route_of(reg, key, entry)
        if owned_by(route) != wid:
            ex = reg.export_entry(key)
            if ex is not None:
                moved.append((key[0], route, ex[0], ex[1]))
    return moved


async def _adopt(service: IMService, graph, problem, lease) -> None:
    service.registry.adopt_entry(graph, problem, lease)


class IMCluster:
    """Consistent-hash routed cluster of :class:`IMService` workers.

    Exposes the same async ``submit/drain/stop`` surface as a single
    service, so :class:`~repro.serve.net.IMNetServer` fronts either
    interchangeably.  Graphs are registered identically on every worker
    (the graph objects are shared read-only; only pools are partitioned).
    """

    def __init__(self, graphs: dict, config: Optional[ServeConfig] = None,
                 *, workers: int = 2, vnodes: int = 64):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.graphs = dict(graphs)
        self.config = config or ServeConfig()
        self.ring = HashRing(vnodes)
        self._workers: Dict[int, _Worker] = {}
        self._next_wid = 0
        self._n_initial = workers
        self.handoffs = 0
        from repro.graph.csr import graph_digest
        self._digests = {name: graph_digest(g)
                         for name, g in self.graphs.items()}

    # -- lifecycle ----------------------------------------------------------
    async def start(self) -> "IMCluster":
        if self._workers:
            raise RuntimeError("cluster already started")
        for _ in range(self._n_initial):
            self._spawn()
        return self

    def _spawn(self) -> int:
        wid = self._next_wid
        self._next_wid += 1
        w = _Worker(wid, self.graphs, self.config)
        w.start()
        self._workers[wid] = w
        self.ring.add(wid)
        return wid

    async def drain(self) -> None:
        for w in list(self._workers.values()):
            await asyncio.wrap_future(w.call(w.service.drain()))

    async def stop(self) -> None:
        for w in list(self._workers.values()):
            w.stop()
        self._workers.clear()

    def spill_pools(self) -> int:
        return sum(w.service.registry.spill_all()
                   for w in self._workers.values())

    # -- routing ------------------------------------------------------------
    def route_key(self, graph: str, problem) -> str:
        """The ring route: the same (graph_digest, pool_digest, θ, mode)
        identity as the registry key, rendered as a string."""
        if graph not in self._digests:
            raise UnknownGraphError(f"unknown graph {graph!r}")
        dig = self._digests[graph]
        model = (problem.model or
                 ("lt" if self.config.solver_opts.get("model") == "lt"
                  else "ic"))
        pd = problem.pool_digest(model=model, graph_digest=dig)
        return f"{dig}|{pd}|{problem.theta}|{problem.mode}"

    @staticmethod
    def _entry_route(registry, key, entry) -> str:
        """Ring route of an existing registry entry — identical string to
        :meth:`route_key` for the problems that built it (``key[1]`` is the
        digest-mixed pool_digest, ``key[2]`` the θ)."""
        return (f"{registry.graph_digest(key[0])}|{key[1]}|{key[2]}"
                f"|{entry.problem.mode}")

    async def submit(self, graph: str, problem, deadline_s=None
                     ) -> ServeResponse:
        wid = self.ring.owner(self.route_key(graph, problem))
        w = self._workers[wid]
        return await asyncio.wrap_future(
            w.call(w.service.submit(graph, problem,
                                    deadline_s=deadline_s)))

    # -- membership / rebalance --------------------------------------------
    def _rebalance(self) -> int:
        """Move every idle entry whose route no longer hashes to its
        current worker (consistent hashing: that set is exactly the
        minimal key range).  Blocking control-plane call."""
        moved = 0
        owned_by = self.ring.owner
        for w in list(self._workers.values()):
            exports = w.call(_export_moving(
                w.service, owned_by, w.wid, self._entry_route)).result()
            for graph, route, problem, lease in exports:
                dest = self._workers[owned_by(route)]
                dest.call(_adopt(dest.service, graph, problem,
                                 lease)).result()
                moved += 1
        self.handoffs += moved
        return moved

    def add_worker(self) -> int:
        """Join: spawn a worker, extend the ring, hand off exactly the
        keys the new vnodes claimed.  Returns the new worker id."""
        wid = self._spawn()
        self._rebalance()
        return wid

    def remove_worker(self, wid: int) -> int:
        """Leave: drain the departing worker, shrink the ring, hand its
        entries to their new owners, stop it.  Returns entries moved."""
        if len(self._workers) <= 1:
            raise ValueError("cannot remove the last worker")
        w = self._workers[wid]
        w.call(w.service.drain()).result()
        self.ring.remove(wid)
        owned_by = self.ring.owner
        exports = w.call(_export_moving(
            w.service, owned_by, w.wid, self._entry_route)).result()
        moved = 0
        for graph, route, problem, lease in exports:
            dest = self._workers[owned_by(route)]
            dest.call(_adopt(dest.service, graph, problem,
                             lease)).result()
            moved += 1
        self.handoffs += moved
        del self._workers[wid]
        w.stop()
        return moved

    # -- stats --------------------------------------------------------------
    async def statsz(self, *, draining: bool = False) -> dict:
        """Aggregated /statsz payload: per-worker ServeStats trees plus
        cluster totals and the ring layout."""
        per_worker = []
        for w in list(self._workers.values()):
            snap = await asyncio.wrap_future(
                w.call(_statsz_async(w.service)))
            snap["worker"] = w.wid
            per_worker.append(snap)
        serve_total: dict = {}
        entries = []
        for snap in per_worker:
            entries.extend(dict(e, worker=snap["worker"])
                           for e in snap["entries"])
            for k, v in snap["serve"].items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    serve_total[k] = serve_total.get(k, 0) + v
        return {"cluster": True, "draining": draining,
                "workers": sorted(w.wid for w in self._workers.values()),
                "handoffs": self.handoffs,
                "serve_total": serve_total, "entries": entries,
                "per_worker": per_worker}


async def _statsz_async(service: IMService) -> dict:
    return service_statsz(service)

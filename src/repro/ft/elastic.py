"""Elastic scaling: re-mesh a job onto a different device count.

Checkpoints are host-unsharded (ckpt/checkpoint.py), so elasticity is:
(1) detect the new device set, (2) build the largest valid mesh, (3) restore
with the new shardings.  The IM pipeline is trivially elastic (stateless
sampling + a global counter); training state re-shards through restore().
"""
from __future__ import annotations

import math

import jax
import numpy as np


def best_mesh_shape(n_devices: int, *, model_parallel: int = 1):
    """(data, model) factorization for an arbitrary device count."""
    model = math.gcd(model_parallel, n_devices)
    return (n_devices // model, model)


def make_elastic_mesh(axis_names=("data", "model"), *, model_parallel: int = 1,
                      devices=None):
    devices = devices if devices is not None else jax.devices()
    shape = best_mesh_shape(len(devices), model_parallel=model_parallel)
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(shape), axis_names)


def rebalance_rounds(total_sets: int, weights: np.ndarray) -> list[int]:
    """Split a sampling quota across shards proportional to throughput."""
    alloc = np.floor(total_sets * weights).astype(int)
    alloc[np.argmax(weights)] += total_sets - alloc.sum()
    return alloc.tolist()
